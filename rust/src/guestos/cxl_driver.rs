//! The guest's CXL driver stack (cxl_acpi + cxl_pci + cxl_mem in one).
//!
//! Everything happens through architectural surfaces:
//!   1. CEDT (CHBS/CFMWS) from ACPI tells it where the host-bridge
//!      component registers and the fixed memory window live.
//!   2. The memdev endpoint is matched by class code 0502xx from the
//!      PCI scan; its DVSECs are walked via config MMIO; the Register
//!      Locator DVSEC yields the BAR-relative component/device blocks.
//!   3. The mailbox (doorbell poll) runs IDENTIFY to learn capacity.
//!   4. HDM decoders are programmed + committed on BOTH the host bridge
//!      and the endpoint, mapping the CFMWS window onto the device.

use anyhow::{bail, Context, Result};

use crate::cxl::regs::{comp, dev, dev_block_ids};
use crate::cxl::mailbox::{opcode, retcode, CAP_MULTIPLE};
use crate::pcie::config_space::{CXL_VENDOR_ID, DVSEC_CXL_DEVICE,
                                DVSEC_REGISTER_LOCATOR};
use crate::pcie::Bdf;

use super::acpi_parse::AcpiInfo;
use super::pci_scan::{self, PciDev};
use super::Platform;

/// What the driver bound and where.
#[derive(Clone, Debug)]
pub struct CxlMemdev {
    pub bdf: Bdf,
    pub serial: u64,
    pub capacity: u64,
    /// Host-physical window the HDM decoders map (the full CFMWS
    /// window; an interleaved device holds every `ways`-th granule).
    pub hpa_base: u64,
    pub hpa_size: u64,
    /// Interleave parameters of the window this device participates in.
    pub window_ways: usize,
    pub window_granularity: u64,
    /// 0 = modulo, 1 = XOR target selection.
    pub window_arith: u8,
    /// This device's slot in the CFMWS target list.
    pub position: usize,
    pub component_block: u64, // absolute MMIO base (endpoint)
    pub device_block: u64,    // absolute MMIO base (mailbox)
    pub hb_component_block: u64,
    pub hb_uid: u32,
}

/// Run a mailbox command through the device block MMIO (doorbell poll —
/// the same loop user-space CXL-CLI ends up in via the kernel ioctl).
pub fn mailbox_command(
    p: &mut dyn Platform,
    devblk: u64,
    op: u16,
    payload: &[u8],
) -> Result<(u16, Vec<u8>)> {
    if p.mmio_read64(devblk + dev::MB_CTRL) & 1 != 0 {
        bail!("mailbox busy before command");
    }
    for (i, chunk) in payload.chunks(8).enumerate() {
        let mut b = [0u8; 8];
        b[..chunk.len()].copy_from_slice(chunk);
        p.mmio_write64(
            devblk + dev::MB_PAYLOAD + (i * 8) as u64,
            u64::from_le_bytes(b),
        );
    }
    p.mmio_write64(
        devblk + dev::MB_CMD,
        (op as u64) | ((payload.len() as u64) << 16),
    );
    p.mmio_write64(devblk + dev::MB_CTRL, 1);
    let mut spins = 0u32;
    while p.mmio_read64(devblk + dev::MB_CTRL) & 1 != 0 {
        spins += 1;
        if spins > 10_000 {
            bail!("mailbox doorbell stuck");
        }
    }
    let code = ((p.mmio_read64(devblk + dev::MB_STATUS) >> 32) & 0xFFFF) as u16;
    let rlen =
        ((p.mmio_read64(devblk + dev::MB_CMD) >> 16) & 0x1F_FFFF) as usize;
    let mut resp = vec![0u8; rlen];
    for i in 0..rlen.div_ceil(8) {
        let v = p.mmio_read64(devblk + dev::MB_PAYLOAD + (i * 8) as u64);
        let at = i * 8;
        let n = (rlen - at).min(8);
        resp[at..at + n].copy_from_slice(&v.to_le_bytes()[..n]);
    }
    Ok((code, resp))
}

/// Program and commit decoder 0 of a component block at `blk` to map
/// `[base, base+size)` with the given interleave encodings (IG:
/// granularity = 256 << ig; IW: ways = 1 << eniw).
fn commit_decoder(
    p: &mut dyn Platform,
    blk: u64,
    base: u64,
    size: u64,
    ig: u8,
    eniw: u8,
) -> Result<()> {
    let dec = blk + comp::HDM_DEC0;
    p.mmio_write32(dec + comp::DEC_BASE_LO, base as u32);
    p.mmio_write32(dec + comp::DEC_BASE_HI, (base >> 32) as u32);
    p.mmio_write32(dec + comp::DEC_SIZE_LO, size as u32);
    p.mmio_write32(dec + comp::DEC_SIZE_HI, (size >> 32) as u32);
    p.mmio_write32(dec + comp::DEC_CTRL, comp::dec_ctrl_commit(ig, eniw));
    let ctrl = p.mmio_read32(dec + comp::DEC_CTRL);
    if ctrl & comp::CTRL_COMMITTED == 0 {
        bail!("HDM decoder refused commit (ctrl={ctrl:#x})");
    }
    // Global enable (bit 1).
    p.mmio_write32(blk + comp::HDM_GLOBAL_CTRL, 0b10);
    Ok(())
}

/// Bind every CXL memdev: endpoints (class 0502, BDF order) pair with
/// the CEDT host bridges (UID order) — the simulator wires root port
/// `i` beneath host bridge `i`, so order-pairing mirrors the ACPI
/// namespace association a full _PRT walk would produce.
pub fn bind_all(
    p: &mut dyn Platform,
    acpi: &AcpiInfo,
    pci_devs: &[PciDev],
) -> Result<Vec<CxlMemdev>> {
    let mut chbs = acpi.chbs.clone();
    chbs.sort_by_key(|c| c.uid);
    if chbs.is_empty() {
        bail!("no CHBS in CEDT — BIOS did not describe a CXL host bridge");
    }
    let mut eps: Vec<&PciDev> = pci_devs
        .iter()
        .filter(|d| {
            !d.is_bridge && d.class[0] == 0x05 && d.class[1] == 0x02
        })
        .collect();
    eps.sort_by_key(|d| d.bdf);
    if eps.is_empty() {
        bail!("no CXL memory device on the PCIe bus");
    }
    if eps.len() != chbs.len() {
        bail!(
            "{} memdev endpoints but {} CXL host bridges",
            eps.len(),
            chbs.len()
        );
    }
    eps.iter()
        .zip(&chbs)
        .map(|(ep, hb)| bind_one(p, acpi, ep, hb))
        .collect()
}

/// Bind one endpoint beneath its host bridge: locate, identify, map.
fn bind_one(
    p: &mut dyn Platform,
    acpi: &AcpiInfo,
    ep: &PciDev,
    chbs: &super::acpi_parse::ChbsInfo,
) -> Result<CxlMemdev> {
    // 1. ACPI side: the window this bridge participates in.
    let cfmws = acpi
        .cfmws
        .iter()
        .find(|w| w.targets.contains(&chbs.uid))
        .context("no CFMWS targeting the host bridge")?;
    let position = cfmws
        .targets
        .iter()
        .position(|&u| u == chbs.uid)
        .unwrap();
    if chbs.cxl_version == 0 {
        bail!("CXL 1.1 host bridges unsupported (RCD mode)");
    }
    let (ecam, ..) = acpi.ecam.context("no MCFG")?;

    // 3. DVSEC walk: confirm CXL device + register locator.
    let cxl_dvsec =
        pci_scan::find_dvsec(p, ecam, ep.bdf, CXL_VENDOR_ID, DVSEC_CXL_DEVICE)
            .context("endpoint lacks CXL Device DVSEC")?;
    let caps = pci_scan::read_cfg_bytes(p, ecam, ep.bdf, cxl_dvsec + 12, 2);
    let cap = u16::from_le_bytes(caps.try_into().unwrap());
    if cap & (1 << 2) == 0 {
        bail!("device is not mem_capable");
    }
    let rl = pci_scan::find_dvsec(
        p,
        ecam,
        ep.bdf,
        CXL_VENDOR_ID,
        DVSEC_REGISTER_LOCATOR,
    )
    .context("endpoint lacks Register Locator DVSEC")?;
    // Register locator payload: walk entries until both blocks found.
    let payload = pci_scan::read_cfg_bytes(p, ecam, ep.bdf, rl + 12, 24);
    let entries =
        crate::cxl::regs::dvsec_payload::parse_register_locator(&payload);
    let mut comp_off = None;
    let mut dev_off = None;
    for (bar, id, offset) in entries {
        let base = ep
            .bars
            .iter()
            .find(|b| b.index == bar as usize)
            .map(|b| b.base + offset);
        match id {
            x if x == dev_block_ids::COMPONENT => comp_off = base,
            x if x == dev_block_ids::DEVICE => dev_off = base,
            _ => {}
        }
    }
    let component_block =
        comp_off.context("register locator lacks component block")?;
    let device_block =
        dev_off.context("register locator lacks device block")?;

    // 4. Wait for media, then IDENTIFY through the mailbox.
    if p.mmio_read64(device_block + dev::MEMDEV_STATUS) & dev::MEDIA_READY == 0
    {
        bail!("media not ready");
    }
    let (code, ident) =
        mailbox_command(p, device_block, opcode::IDENTIFY_MEMORY_DEVICE, &[])?;
    if code != retcode::SUCCESS {
        bail!("IDENTIFY failed with code {code:#x}");
    }
    let capacity =
        u64::from_le_bytes(ident[16..24].try_into().unwrap()) * CAP_MULTIPLE;
    let serial = u64::from_le_bytes(ident[64..72].try_into().unwrap());
    if capacity == 0 {
        bail!("device reports zero capacity");
    }
    let ways = cfmws.targets.len();
    // An N-way window spreads every member across the whole window;
    // each decoder maps the full window with the interleave fields set.
    let map_size = cfmws.window_size.min(capacity * ways as u64);
    if !cfmws.granularity.is_power_of_two() || cfmws.granularity < 256 {
        bail!("bad CFMWS granularity {:#x}", cfmws.granularity);
    }
    let ig = (cfmws.granularity.trailing_zeros() - 8) as u8;
    let eniw = ways.trailing_zeros() as u8;

    // 5. HDM decoders: endpoint first, then host bridge (commit order
    // matters on real hardware: leaf before root).
    commit_decoder(p, component_block, cfmws.base_hpa, map_size, ig, eniw)?;
    commit_decoder(p, chbs.base, cfmws.base_hpa, map_size, ig, eniw)?;

    Ok(CxlMemdev {
        bdf: ep.bdf,
        serial,
        capacity,
        hpa_base: cfmws.base_hpa,
        hpa_size: map_size,
        window_ways: ways,
        window_granularity: cfmws.granularity,
        window_arith: cfmws.arith,
        position,
        component_block,
        device_block,
        hb_component_block: chbs.base,
        hb_uid: chbs.uid,
    })
}
