//! User-space tool emulations: `cxl list`, `cxl create-region`,
//! `ndctl`-style onlining and `numactl`.
//!
//! The paper: "the CXL Command Line Interface (CXL-CLI) toolchain
//! [..] in conjunction with numactl is used to 'online' and expose the
//! CXL memory as CPU-less NUMA node". These commands operate strictly
//! through the bound driver state and the mailbox register surface —
//! the same layering as ndctl-on-ioctl-on-mailbox in a real system.

use anyhow::{bail, Context, Result};

use crate::cxl::mailbox::{opcode, retcode, CAP_MULTIPLE};

use super::cxl_driver::{mailbox_command, CxlMemdev};
use super::numa::{MemPolicy, NumaNode, PageAlloc};
use super::Platform;

/// `cxl list` — JSON-ish description of one bound memdev (`mem{idx}`).
pub fn cxl_list(
    p: &mut dyn Platform,
    md: &CxlMemdev,
    idx: usize,
) -> Result<String> {
    let (code, resp) =
        mailbox_command(p, md.device_block, opcode::GET_PARTITION_INFO, &[])?;
    if code != retcode::SUCCESS {
        bail!("GET_PARTITION_INFO failed: {code:#x}");
    }
    let vol = u64::from_le_bytes(resp[0..8].try_into().unwrap()) * CAP_MULTIPLE;
    Ok(format!(
        "{{\"memdev\":\"mem{}\",\"pci\":\"{}\",\"serial\":\"{:#x}\",\
         \"ram_size\":{},\"volatile\":{},\"host_window\":\"{:#x}\",\
         \"interleave\":{{\"ways\":{},\"granularity\":{},\"position\":{}}}}}",
        idx,
        md.bdf,
        md.serial,
        md.capacity,
        vol,
        md.hpa_base,
        md.window_ways,
        md.window_granularity,
        md.position
    ))
}

/// A created (but not yet onlined) region — `cxl create-region` output.
#[derive(Clone, Debug)]
pub struct CxlRegion {
    pub base: u64,
    pub size: u64,
    pub node: u32,
}

/// `cxl create-region -t ram` — assemble a RAM region out of the
/// memdevs decoded into one interleave-set window (an SLD region passes
/// a single-element slice). `size` of 0 means "whole window".
pub fn cxl_create_region(
    p: &mut dyn Platform,
    group: &[&CxlMemdev],
    size: u64,
    node: u32,
) -> Result<CxlRegion> {
    let md = *group.first().context("region needs at least one memdev")?;
    if group.iter().any(|m| m.hpa_base != md.hpa_base) {
        bail!("region members must share one window");
    }
    if group.len() != md.window_ways {
        bail!(
            "window is {}-way but {} memdevs were assembled",
            md.window_ways,
            group.len()
        );
    }
    let size = if size == 0 { md.hpa_size } else { size };
    if size > md.hpa_size {
        bail!(
            "region {size:#x} exceeds decoded window {:#x}",
            md.hpa_size
        );
    }
    // Sanity-check every member still responds (health check).
    for m in group {
        let (code, _) =
            mailbox_command(p, m.device_block, opcode::GET_HEALTH_INFO, &[])?;
        if code != retcode::SUCCESS {
            bail!("device {} unhealthy: {code:#x}", m.bdf);
        }
    }
    Ok(CxlRegion { base: md.hpa_base, size, node })
}

/// `daxctl online-memory` / `ndctl` equivalent: register the region as
/// a CPU-less NUMA node and mark it online in the page allocator.
pub fn online_region(
    alloc: &mut PageAlloc,
    region: &CxlRegion,
) -> Result<u32> {
    let id = region.node;
    if (id as usize) < alloc.nodes.len() {
        // Node exists (SRAT pre-declared it): just online.
        if alloc.nodes[id as usize].online {
            bail!("node {id} already online");
        }
    } else {
        if id as usize != alloc.nodes.len() {
            bail!("non-dense node id {id}");
        }
        alloc.add_node(NumaNode::new(id, region.base, region.size, false));
    }
    alloc.online(id);
    Ok(id)
}

/// `daxctl offline-memory` equivalent, the hot-remove half: take the
/// zNUMA node offline so the region can be released back to the fabric
/// manager. Mirrors Linux semantics: offlining fails while pages are in
/// use (we model the no-migration case — busy memory blocks refuse to
/// offline), so a workload actively using the node blocks the remove.
pub fn offline_region(alloc: &mut PageAlloc, node: u32) -> Result<()> {
    let n = alloc
        .nodes
        .get(node as usize)
        .with_context(|| format!("no NUMA node {node}"))?;
    if !n.online {
        bail!("node {node} already offline");
    }
    let busy = alloc.pages_in_use(node);
    if busy > 0 {
        bail!("node {node} has {busy} page(s) in use");
    }
    alloc.offline(node);
    Ok(())
}

/// `numactl --interleave=.. / --membind=.. ./workload` — just resolves
/// the policy string; the workload's address space carries it.
pub fn numactl(policy: &str) -> Result<MemPolicy> {
    MemPolicy::parse(policy).context("numactl: bad policy")
}

/// Flat-memory mode (paper §IV): the CXL capacity joins the *same*
/// node as system DRAM — the OS sees one big pool. Implemented by
/// growing node 0's range bookkeeping with a second extent.
/// (Allocator-visible effect: node 0 gains the window's pages.)
pub fn online_flat(
    alloc: &mut PageAlloc,
    region: &CxlRegion,
) -> Result<()> {
    // Represent the extra extent as a node that *reports* as node 0.
    // PageAlloc requires dense ids, so flat mode adds the extent as the
    // next node but flags it CPU-having (same affinity as node 0) —
    // policies of "local" will spill into it naturally.
    let id = alloc.nodes.len() as u32;
    let mut n = NumaNode::new(id, region.base, region.size, true);
    n.online = true;
    alloc.add_node(n);
    alloc.online(id);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guestos::numa::NumaNode;

    fn alloc_with_dram() -> PageAlloc {
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 0, 1 << 20, true));
        pa.online(0);
        pa
    }

    #[test]
    fn online_region_creates_znuma_node() {
        let mut pa = alloc_with_dram();
        let r = CxlRegion { base: 4 << 30, size: 1 << 20, node: 1 };
        let id = online_region(&mut pa, &r).unwrap();
        assert_eq!(id, 1);
        assert!(pa.nodes[1].online);
        assert!(!pa.nodes[1].has_cpus, "zNUMA node must be CPU-less");
        // Double online fails.
        assert!(online_region(&mut pa, &r).is_err());
    }

    #[test]
    fn offline_refuses_busy_node_then_succeeds_when_free() {
        let mut pa = alloc_with_dram();
        let r = CxlRegion { base: 4 << 30, size: 1 << 20, node: 1 };
        let id = online_region(&mut pa, &r).unwrap();
        let pol = MemPolicy::Bind { nodes: vec![id] };
        let page = pa.alloc_page(&pol, 0).unwrap();
        // Busy node refuses to offline (no-migration model).
        assert!(offline_region(&mut pa, id).is_err());
        assert!(pa.nodes[id as usize].online);
        // Freeing the page unblocks the remove.
        pa.free_page(page);
        offline_region(&mut pa, id).unwrap();
        assert!(!pa.nodes[id as usize].online);
        // Double offline fails; re-onlining works (hot re-add).
        assert!(offline_region(&mut pa, id).is_err());
        online_region(&mut pa, &r).unwrap();
        assert!(pa.nodes[id as usize].online);
    }

    #[test]
    fn flat_mode_extends_local_allocation() {
        let mut pa = alloc_with_dram();
        let r = CxlRegion { base: 4 << 30, size: 1 << 20, node: 0 };
        online_flat(&mut pa, &r).unwrap();
        // Exhaust node 0 (256 pages) + spill into the flat extent.
        let pol = MemPolicy::Local { home: 0 };
        let mut spilled = false;
        for seq in 0..300u64 {
            let p = pa.alloc_page(&pol, seq).unwrap();
            if p >= 4 << 30 {
                spilled = true;
            }
        }
        assert!(spilled, "flat mode must absorb overflow");
    }

    #[test]
    fn numactl_parses() {
        assert!(numactl("interleave:0=3,1=1").is_ok());
        assert!(numactl("garbage").is_err());
    }
}
