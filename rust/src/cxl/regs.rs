//! CXL register surfaces (CXL 2.0+), Fig. 3's three register sets.
//!
//! Set 1 — Root-Complex DVSECs carried in PCIe config space:
//!   GPF, Flexbus Port, CXL Device, and the Register Locator that points
//!   the driver at the memory-mapped blocks below.
//! Set 2 — Host-bridge / component registers (BAR-mapped, 64 KiB):
//!   capability directory + the **HDM decoders** that place the device's
//!   memory into the host physical address map.
//! Set 3 — Device registers (BAR-mapped, 4 KiB): capabilities array,
//!   **mailbox** (+ doorbell) and the memory-device status register.
//!
//! Layouts follow CXL 2.0 §8.1/§8.2 in structure (field packing inside a
//! register is faithful where the guest driver reads it; unused fields
//! are present but zero). Deviations are noted inline.

/// ---- Component register block (Set 2) --------------------------------
/// Offsets inside the 64 KiB component block (BAR0 of the endpoint /
/// host-bridge window). CXL 2.0 puts CXL.cache/CXL.mem registers in the
/// 0x1000-0x2000 range discovered via a capability directory at 0x0;
/// we model the directory with one entry pointing at the HDM block.
pub mod comp {
    /// Capability directory header: [15:0] id=0x0001 (CXL cap), [23:16]
    /// version, [31:24] entry count.
    pub const CAP_HDR: u64 = 0x0000;
    /// Directory entry 0: points at the HDM decoder capability block.
    pub const CAP_ENTRY0: u64 = 0x0004;

    /// HDM decoder capability block (CXL 2.0 §8.2.5.12).
    pub const HDM_BASE: u64 = 0x1000;
    /// [3:0] decoder count (encoded: 0 => 1 decoder, 1 => 2, ...).
    pub const HDM_CAP: u64 = HDM_BASE;
    /// bit[1] enable.
    pub const HDM_GLOBAL_CTRL: u64 = HDM_BASE + 0x04;
    /// Per-decoder stride and register offsets.
    pub const HDM_DEC_STRIDE: u64 = 0x20;
    pub const HDM_DEC0: u64 = HDM_BASE + 0x10;
    pub const DEC_BASE_LO: u64 = 0x00;
    pub const DEC_BASE_HI: u64 = 0x04;
    pub const DEC_SIZE_LO: u64 = 0x08;
    pub const DEC_SIZE_HI: u64 = 0x0C;
    /// bit[9] commit (W), bit[10] committed (RO), bits[3:0] IG
    /// (granularity = 256 << IG), bits[7:4] IW (ways = 1 << IW) — the
    /// CXL 2.0 §8.2.5.12.7 interleave fields, programmed non-zero when
    /// the decoder participates in a multi-device window.
    pub const DEC_CTRL: u64 = 0x10;
    /// Decoder DPA base ("DPA skip" in CXL 2.0 device decoders,
    /// compacted into two dwords at +0x14/+0x18 of the stride): the
    /// device-physical address this decoder's window maps onto —
    /// non-zero for the upper logical-device slices of an MLD.
    pub const DEC_DPA_LO: u64 = 0x14;
    pub const DEC_DPA_HI: u64 = 0x18;

    pub const CTRL_COMMIT: u32 = 1 << 9;
    pub const CTRL_COMMITTED: u32 = 1 << 10;
    pub const CTRL_IG_MASK: u32 = 0xF;
    pub const CTRL_IW_SHIFT: u32 = 4;
    pub const CTRL_IW_MASK: u32 = 0xF << CTRL_IW_SHIFT;

    /// The DEC_CTRL commit value with interleave fields packed — the
    /// single encoding shared by the guest driver and device-side
    /// helpers.
    pub fn dec_ctrl_commit(ig: u8, eniw: u8) -> u32 {
        CTRL_COMMIT
            | (ig as u32 & CTRL_IG_MASK)
            | (((eniw as u32) << CTRL_IW_SHIFT) & CTRL_IW_MASK)
    }

    pub const BLOCK_SIZE: u64 = 0x10000;
}

/// ---- Device register block (Set 3) ------------------------------------
pub mod dev {
    /// Device capabilities array header (§8.2.8.1): [15:0] cap-array id
    /// 0x0000, [47:32] entry count. One entry: the primary mailbox.
    pub const CAP_ARRAY: u64 = 0x0000;
    pub const CAP_ENTRY0: u64 = 0x0010;

    /// Mailbox registers (§8.2.8.4).
    pub const MB_BASE: u64 = 0x0020;
    /// [4:0] payload size as log2 (we expose 2^9 = 512 B).
    pub const MB_CAPS: u64 = MB_BASE;
    /// bit[0] doorbell.
    pub const MB_CTRL: u64 = MB_BASE + 0x04;
    /// [15:0] opcode, [36:16] payload length. 64-bit register.
    pub const MB_CMD: u64 = MB_BASE + 0x08;
    /// [47:32] return code. 64-bit register.
    pub const MB_STATUS: u64 = MB_BASE + 0x10;
    /// Background-op status (unused by SLD commands; present).
    pub const MB_BG_STATUS: u64 = MB_BASE + 0x18;
    /// Payload area.
    pub const MB_PAYLOAD: u64 = MB_BASE + 0x20;
    pub const MB_PAYLOAD_BYTES: usize = 512;

    /// Memory-device status register (§8.2.8.3): bit[1] media ready.
    pub const MEMDEV_STATUS: u64 = 0x0400;
    pub const MEDIA_READY: u64 = 1 << 1;
    /// Model-specific summary bit: record(s) waiting in the device's
    /// Event Log (stands in for the event-interrupt MSI/MSI-X the spec
    /// delivers alongside the doorbell; the guest polls it before
    /// issuing `GET_EVENT_RECORDS`).
    pub const EVENT_PENDING: u64 = 1 << 5;

    pub const BLOCK_SIZE: u64 = 0x1000;
}

/// ---- DVSEC payload builders (Set 1) ------------------------------------
/// Payload bytes begin *after* the 12-byte DVSEC header that
/// `ConfigSpace::add_dvsec` emits, i.e. payload offset 0 == DVSEC+12.
pub mod dvsec_payload {
    /// PCIe DVSEC for CXL Devices (§8.1.3): capability + control +
    /// status (+ capability2 with mem size multiplier).
    /// cap bit2 = mem_capable, bit4 = HDM count (1 decoder), bit14 =
    /// mailbox ready reporting.
    pub fn cxl_device(mem_size: u64) -> Vec<u8> {
        let mut p = vec![0u8; 0x24];
        let cap: u16 = (1 << 2) | (1 << 4) | (1 << 14);
        p[0..2].copy_from_slice(&cap.to_le_bytes());
        let ctrl: u16 = 1 << 2; // mem_enable
        p[2..4].copy_from_slice(&ctrl.to_le_bytes());
        // Range 1 Size High/Low at payload +0x0C/+0x10 (spec DVSEC+0x18):
        // size in 256 MiB multiples per spec; low dword carries
        // memory_info_valid (bit0) and memory_active (bit1).
        let hi = (mem_size >> 32) as u32;
        let lo_flags: u32 = (mem_size as u32 & 0xF000_0000) | 0b11;
        p[0x0C..0x10].copy_from_slice(&hi.to_le_bytes());
        p[0x10..0x14].copy_from_slice(&lo_flags.to_le_bytes());
        p
    }

    /// GPF (Global Persistent Flush) Device DVSEC (§8.1.7): phase
    /// timeouts. Volatile expander: zeros are architecturally fine, but
    /// the block must exist for the driver's feature walk.
    pub fn gpf_device() -> Vec<u8> {
        let mut p = vec![0u8; 0x10];
        p[0] = 0x0F; // phase-2 duration scale/values (benign defaults)
        p
    }

    /// Flex Bus Port DVSEC (§8.1.5): negotiated link state.
    /// cap bit2 = mem_capable; status bit2 = mem_enabled.
    pub fn flexbus_port() -> Vec<u8> {
        let mut p = vec![0u8; 0x10];
        let cap: u16 = 1 << 2;
        p[0..2].copy_from_slice(&cap.to_le_bytes());
        let status: u16 = 1 << 2;
        p[8..10].copy_from_slice(&status.to_le_bytes());
        p
    }

    /// Register Locator DVSEC (§8.1.9): entries of (BAR index, block id,
    /// offset within BAR). Entry = 2 dwords: lo = bar[2:0] | id[15:8] |
    /// offset_lo[31:16]; hi = offset_hi.
    pub fn register_locator(entries: &[(u8, u8, u64)]) -> Vec<u8> {
        let mut p = Vec::with_capacity(entries.len() * 8);
        for &(bar, block_id, offset) in entries {
            assert_eq!(offset & 0xFFFF, offset & 0xFFFF); // 64K aligned use
            let lo: u32 = (bar as u32 & 0x7)
                | ((block_id as u32) << 8)
                | ((offset as u32 & 0xFFFF_0000) >> 0);
            let hi: u32 = (offset >> 32) as u32;
            p.extend_from_slice(&lo.to_le_bytes());
            p.extend_from_slice(&hi.to_le_bytes());
        }
        p
    }

    /// Parse a register-locator payload (driver side).
    pub fn parse_register_locator(p: &[u8]) -> Vec<(u8, u8, u64)> {
        p.chunks_exact(8)
            .map(|c| {
                let lo = u32::from_le_bytes(c[0..4].try_into().unwrap());
                let hi = u32::from_le_bytes(c[4..8].try_into().unwrap());
                let bar = (lo & 0x7) as u8;
                let id = ((lo >> 8) & 0xFF) as u8;
                let off = ((hi as u64) << 32) | (lo as u64 & 0xFFFF_0000);
                (bar, id, off)
            })
            .collect()
    }
}

/// The component register block state machine (HDM decoders).
#[derive(Clone, Debug)]
pub struct ComponentRegs {
    words: std::collections::BTreeMap<u64, u32>,
    pub decoder_count: usize,
}

impl ComponentRegs {
    pub fn new(decoder_count: usize) -> Self {
        assert!((1..=10).contains(&decoder_count));
        let mut r = ComponentRegs {
            words: Default::default(),
            decoder_count,
        };
        // Directory: id 0x0001, version 1, 1 entry; entry points at HDM.
        r.words.insert(comp::CAP_HDR, 0x0001 | (1 << 16) | (1 << 24));
        r.words
            .insert(comp::CAP_ENTRY0, (0x0005 << 0) | ((comp::HDM_BASE as u32) << 8));
        r.words
            .insert(comp::HDM_CAP, (decoder_count as u32 - 1) & 0xF);
        r.words.insert(comp::HDM_GLOBAL_CTRL, 0);
        r
    }

    fn dec_reg(&self, i: usize, off: u64) -> u64 {
        comp::HDM_DEC0 + (i as u64) * comp::HDM_DEC_STRIDE + off
    }

    pub fn read32(&self, off: u64) -> u32 {
        *self.words.get(&off).unwrap_or(&0)
    }

    pub fn write32(&mut self, off: u64, v: u32) {
        // Commit handling: setting COMMIT latches COMMITTED if the
        // decoder programming is sane (non-zero size, aligned base).
        for i in 0..self.decoder_count {
            if off == self.dec_reg(i, comp::DEC_CTRL) {
                let mut val = v & !comp::CTRL_COMMITTED;
                if v & comp::CTRL_COMMIT != 0 {
                    let (base, size) = self.decoder_range(i);
                    if size > 0 && base % 4096 == 0 && size % 4096 == 0 {
                        val |= comp::CTRL_COMMITTED;
                    }
                }
                self.words.insert(off, val);
                return;
            }
        }
        self.words.insert(off, v);
    }

    pub fn decoder_range(&self, i: usize) -> (u64, u64) {
        let base = (self.read32(self.dec_reg(i, comp::DEC_BASE_LO)) as u64)
            | ((self.read32(self.dec_reg(i, comp::DEC_BASE_HI)) as u64) << 32);
        let size = (self.read32(self.dec_reg(i, comp::DEC_SIZE_LO)) as u64)
            | ((self.read32(self.dec_reg(i, comp::DEC_SIZE_HI)) as u64) << 32);
        (base, size)
    }

    pub fn decoder_committed(&self, i: usize) -> bool {
        self.read32(self.dec_reg(i, comp::DEC_CTRL)) & comp::CTRL_COMMITTED
            != 0
    }

    pub fn hdm_enabled(&self) -> bool {
        self.read32(comp::HDM_GLOBAL_CTRL) & 0b10 != 0
    }

    /// The committed, enabled address ranges (host physical -> device).
    pub fn committed_ranges(&self) -> Vec<(u64, u64)> {
        if !self.hdm_enabled() {
            return vec![];
        }
        (0..self.decoder_count)
            .filter(|&i| self.decoder_committed(i))
            .map(|i| self.decoder_range(i))
            .filter(|&(_, s)| s > 0)
            .collect()
    }

    /// Driver-side helper: program decoder i to [base, base+size).
    pub fn program_decoder(&mut self, i: usize, base: u64, size: u64) {
        self.program_decoder_interleaved(i, base, size, 0, 0);
    }

    /// Program decoder i with interleave fields: granularity 256 << ig,
    /// ways 1 << eniw (0/0 = the plain SLD decode).
    pub fn program_decoder_interleaved(
        &mut self,
        i: usize,
        base: u64,
        size: u64,
        ig: u8,
        eniw: u8,
    ) {
        self.write32(self.dec_reg(i, comp::DEC_BASE_LO), base as u32);
        self.write32(self.dec_reg(i, comp::DEC_BASE_HI), (base >> 32) as u32);
        self.write32(self.dec_reg(i, comp::DEC_SIZE_LO), size as u32);
        self.write32(self.dec_reg(i, comp::DEC_SIZE_HI), (size >> 32) as u32);
        self.write32(
            self.dec_reg(i, comp::DEC_CTRL),
            comp::dec_ctrl_commit(ig, eniw),
        );
    }

    /// The device-physical base decoder i maps onto (0 unless the
    /// decoder carries an MLD slice).
    pub fn decoder_dpa_skip(&self, i: usize) -> u64 {
        (self.read32(self.dec_reg(i, comp::DEC_DPA_LO)) as u64)
            | ((self.read32(self.dec_reg(i, comp::DEC_DPA_HI)) as u64)
                << 32)
    }

    /// Program decoder i as a logical-device slice: a plain 1-way decode
    /// of `[base, base+size)` onto device-physical `[dpa, dpa+size)`.
    pub fn program_decoder_at(
        &mut self,
        i: usize,
        base: u64,
        size: u64,
        dpa: u64,
    ) {
        self.write32(self.dec_reg(i, comp::DEC_DPA_LO), dpa as u32);
        self.write32(self.dec_reg(i, comp::DEC_DPA_HI), (dpa >> 32) as u32);
        self.program_decoder(i, base, size);
    }

    /// The committed interleave parameters of decoder i:
    /// `(ways, granularity_bytes)`.
    pub fn decoder_interleave(&self, i: usize) -> (usize, u64) {
        let ctrl = self.read32(self.dec_reg(i, comp::DEC_CTRL));
        let ig = ctrl & comp::CTRL_IG_MASK;
        let eniw = (ctrl & comp::CTRL_IW_MASK) >> comp::CTRL_IW_SHIFT;
        (1usize << eniw, 256u64 << ig)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directory_points_at_hdm() {
        let r = ComponentRegs::new(1);
        let hdr = r.read32(comp::CAP_HDR);
        assert_eq!(hdr & 0xFFFF, 0x0001);
        assert_eq!(hdr >> 24, 1); // one entry
        let e0 = r.read32(comp::CAP_ENTRY0);
        assert_eq!((e0 >> 8) as u64, comp::HDM_BASE);
    }

    #[test]
    fn decoder_commit_flow() {
        let mut r = ComponentRegs::new(2);
        assert!(!r.decoder_committed(0));
        r.program_decoder(0, 0x1_0000_0000, 4 << 30);
        assert!(r.decoder_committed(0));
        assert_eq!(r.decoder_range(0), (0x1_0000_0000, 4 << 30));
        // Not globally enabled yet -> no ranges.
        assert!(r.committed_ranges().is_empty());
        r.write32(comp::HDM_GLOBAL_CTRL, 0b10);
        assert_eq!(r.committed_ranges(), vec![(0x1_0000_0000, 4 << 30)]);
    }

    #[test]
    fn interleave_fields_roundtrip_through_commit() {
        let mut r = ComponentRegs::new(1);
        // 2-way @ 1 KiB: ig = 2 (256 << 2), eniw = 1.
        r.program_decoder_interleaved(0, 4 << 30, 8 << 30, 2, 1);
        assert!(r.decoder_committed(0));
        assert_eq!(r.decoder_interleave(0), (2, 1024));
        // Plain decoder reads back as 1-way / 256 B.
        let mut p = ComponentRegs::new(1);
        p.program_decoder(0, 4 << 30, 4 << 30);
        assert_eq!(p.decoder_interleave(0), (1, 256));
    }

    #[test]
    fn dpa_skip_roundtrips_per_decoder() {
        let mut r = ComponentRegs::new(2);
        r.program_decoder_at(0, 4 << 30, 2 << 30, 0);
        r.program_decoder_at(1, 8 << 30, 2 << 30, 2 << 30);
        assert!(r.decoder_committed(0) && r.decoder_committed(1));
        assert_eq!(r.decoder_dpa_skip(0), 0);
        assert_eq!(r.decoder_dpa_skip(1), 2 << 30);
        assert_eq!(r.decoder_range(1), (8 << 30, 2 << 30));
    }

    #[test]
    fn commit_rejects_unaligned() {
        let mut r = ComponentRegs::new(1);
        r.write32(comp::HDM_DEC0 + comp::DEC_BASE_LO, 123); // unaligned
        r.write32(comp::HDM_DEC0 + comp::DEC_SIZE_LO, 4096);
        r.write32(comp::HDM_DEC0 + comp::DEC_CTRL, comp::CTRL_COMMIT);
        assert!(!r.decoder_committed(0));
    }

    #[test]
    fn register_locator_roundtrip() {
        let entries = vec![
            (0u8, super::super::regs::dev_block_ids::COMPONENT, 0u64),
            (2u8, super::super::regs::dev_block_ids::DEVICE, 0x1_0000u64),
        ];
        let p = dvsec_payload::register_locator(&entries);
        assert_eq!(dvsec_payload::parse_register_locator(&p), entries);
    }

    #[test]
    fn cxl_device_dvsec_flags() {
        let p = dvsec_payload::cxl_device(4 << 30);
        let cap = u16::from_le_bytes(p[0..2].try_into().unwrap());
        assert!(cap & (1 << 2) != 0, "mem_capable");
        let lo = u32::from_le_bytes(p[0x10..0x14].try_into().unwrap());
        assert!(lo & 0b11 == 0b11, "info valid + active");
    }
}

/// Register-block ids used in the Register Locator (re-export for
/// convenience alongside `pcie::config_space`).
pub mod dev_block_ids {
    pub const COMPONENT: u8 = 0x01;
    pub const BAR_VIRT: u8 = 0x02;
    pub const DEVICE: u8 = 0x03;
}
