//! CXL.mem transaction layer (paper Fig. 4).
//!
//! Host-to-device traffic travels on the **M2S** (Master-to-Subordinate)
//! channels, device-to-host on **S2M**:
//!
//! * M2S **Req**        — MemRd* reads (no data)           -> 1 flit
//! * M2S **RwD**        — MemWr writes (request with data) -> header + 64B
//! * S2M **NDR**        — No-Data Response: write completions (Cmp)
//! * S2M **DRS**        — Data Response: read data (MemData), hdr + 64B
//!
//! The root complex *packetizes* host cache-line requests into these
//! packets (opcode in the header), the endpoint *de-packetizes* and
//! hands them to its media controller; responses take the reverse path.
//! Opcodes and the packet header layout follow CXL 2.0 §3.3.

use crate::sim::{MemCmd, Packet};

/// M2S request opcodes (CXL 2.0 table 3-22; subset an SLD sees).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum M2SOpcode {
    /// MemRd — read request, expects DRS MemData.
    MemRd,
    /// MemRdData — read, data-only semantics (no metadata).
    MemRdData,
    /// MemInv — invalidate (metadata only; used by back-invalidate
    /// flows; carried for completeness).
    MemInv,
    /// MemWr — full-line write (travels on RwD with 64 B payload).
    MemWr,
    /// MemWrPtl — partial write (RwD + byte-enables).
    MemWrPtl,
}

impl M2SOpcode {
    /// Encoding per spec table (3-bit MemOpcode field).
    pub fn encode(&self) -> u8 {
        match self {
            M2SOpcode::MemInv => 0b000,
            M2SOpcode::MemRd => 0b001,
            M2SOpcode::MemRdData => 0b010,
            M2SOpcode::MemWr => 0b001, // RwD namespace
            M2SOpcode::MemWrPtl => 0b010,
        }
    }

    pub fn carries_data(&self) -> bool {
        matches!(self, M2SOpcode::MemWr | M2SOpcode::MemWrPtl)
    }
}

/// S2M response opcodes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum S2MOpcode {
    /// NDR Cmp — completion for writes (and MemInv).
    Cmp,
    /// DRS MemData — read data return.
    MemData,
    /// BISnp — back-invalidate snoop (CXL 3.x): the device asks a
    /// sharer host to invalidate a line its snoop filter tracks.
    /// Header-only; the host answers with an M2S BIRsp.
    BISnpInv,
}

impl S2MOpcode {
    pub fn carries_data(&self) -> bool {
        matches!(self, S2MOpcode::MemData)
    }
}

/// Direction + channel classification for stats. The two BI channels
/// are CXL 3.x additions: device-initiated requests (S2M BISnp) and
/// their host responses (M2S BIRsp) ride dedicated channels precisely
/// so they never contend with — or deadlock against — the credited
/// M2S request path they may be blocking.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Channel {
    M2SReq,
    M2SRwD,
    S2MNdr,
    S2MDrs,
    /// Device -> host back-invalidate snoop (CXL 3.x BISnp).
    S2MBISnp,
    /// Host -> device back-invalidate response (CXL 3.x BIRsp);
    /// carries the dirty line when the host held it Modified.
    M2SBIRsp,
}

/// One CXL.mem protocol packet as carried over the link.
#[derive(Clone, Debug)]
pub struct CxlMemPacket {
    pub channel: Channel,
    pub m2s: Option<M2SOpcode>,
    pub s2m: Option<S2MOpcode>,
    /// Host physical address (line-aligned).
    pub addr: u64,
    /// Tag correlating request and response (CXL tag field).
    pub tag: u16,
    /// Total wire bytes: header + optional 64 B data slots.
    pub wire_bytes: u64,
    /// Original simulator request id (correlation only, not on wire).
    pub req_id: u64,
}

/// CXL.mem header size on the wire: we charge one 16-byte slot
/// (a 528-bit flit carries 4 slots; header occupies one).
pub const HEADER_BYTES: u64 = 16;
pub const DATA_BYTES: u64 = 64;

/// Packetizer (root-complex side): host request -> M2S packet.
/// Returns `None` for host commands that never cross the link
/// (coherence-internal traffic stays above the RC).
pub fn packetize(pkt: &Packet, tag: u16) -> Option<CxlMemPacket> {
    let (channel, op, bytes) = match pkt.cmd {
        MemCmd::ReadReq => {
            (Channel::M2SReq, M2SOpcode::MemRd, HEADER_BYTES)
        }
        MemCmd::WriteReq | MemCmd::WritebackDirty => (
            Channel::M2SRwD,
            M2SOpcode::MemWr,
            HEADER_BYTES + DATA_BYTES,
        ),
        _ => return None,
    };
    Some(CxlMemPacket {
        channel,
        m2s: Some(op),
        s2m: None,
        addr: pkt.addr,
        tag,
        wire_bytes: bytes,
        req_id: pkt.id,
    })
}

/// Packetizer for a shared-region store miss (RFO): MemInv on the Req
/// channel — a metadata-only ownership request. The device invalidates
/// every other sharer (back-invalidate) and returns the line via DRS
/// MemData ([`make_response`] already maps non-data M2S opcodes to
/// DRS), so one round trip both fetches and claims the line.
pub fn packetize_rfo(pkt: &Packet, tag: u16) -> CxlMemPacket {
    CxlMemPacket {
        channel: Channel::M2SReq,
        m2s: Some(M2SOpcode::MemInv),
        s2m: None,
        addr: pkt.addr,
        tag,
        wire_bytes: HEADER_BYTES,
        req_id: pkt.id,
    }
}

/// Build a device-initiated back-invalidate snoop (S2M BISnp) for the
/// host-physical line `addr`. Header-only on the wire.
pub fn make_bi_snoop(addr: u64, tag: u16, req_id: u64) -> CxlMemPacket {
    CxlMemPacket {
        channel: Channel::S2MBISnp,
        m2s: None,
        s2m: Some(S2MOpcode::BISnpInv),
        addr,
        tag,
        wire_bytes: HEADER_BYTES,
        req_id,
    }
}

/// Build the host's answer to a BISnp (M2S BIRsp). A clean line acks
/// with the header alone; a Modified line carries its 64 B of dirty
/// data back to the device with the response.
pub fn make_bi_response(
    addr: u64,
    tag: u16,
    req_id: u64,
    dirty: bool,
) -> CxlMemPacket {
    CxlMemPacket {
        channel: Channel::M2SBIRsp,
        m2s: Some(M2SOpcode::MemInv),
        s2m: None,
        addr,
        tag,
        wire_bytes: if dirty {
            HEADER_BYTES + DATA_BYTES
        } else {
            HEADER_BYTES
        },
        req_id,
    }
}

/// De-packetizer (endpoint side): M2S packet -> media operation.
/// Returns (is_write, addr).
pub fn depacketize(p: &CxlMemPacket) -> (bool, u64) {
    let op = p.m2s.expect("depacketize on non-M2S packet");
    (op.carries_data(), p.addr)
}

/// Build the S2M response for an M2S request.
pub fn make_response(req: &CxlMemPacket) -> CxlMemPacket {
    let op = req.m2s.expect("response to non-M2S packet");
    let (channel, s2m, bytes) = if op.carries_data() {
        // Writes complete with NDR Cmp (paper: "S2M No Data Response
        // (NDR): completion of Write Requests").
        (Channel::S2MNdr, S2MOpcode::Cmp, HEADER_BYTES)
    } else {
        // Reads return DRS MemData.
        (Channel::S2MDrs, S2MOpcode::MemData, HEADER_BYTES + DATA_BYTES)
    };
    CxlMemPacket {
        channel,
        m2s: None,
        s2m: Some(s2m),
        addr: req.addr,
        tag: req.tag,
        wire_bytes: bytes,
        req_id: req.req_id,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(cmd: MemCmd) -> Packet {
        Packet::new(7, cmd, 0x1000, 64, 0, 0)
    }

    #[test]
    fn read_packetizes_to_m2s_req() {
        let p = packetize(&req(MemCmd::ReadReq), 3).unwrap();
        assert_eq!(p.channel, Channel::M2SReq);
        assert_eq!(p.m2s, Some(M2SOpcode::MemRd));
        assert_eq!(p.wire_bytes, HEADER_BYTES);
        assert_eq!(p.tag, 3);
    }

    #[test]
    fn write_packetizes_to_rwd_with_data() {
        let p = packetize(&req(MemCmd::WriteReq), 1).unwrap();
        assert_eq!(p.channel, Channel::M2SRwD);
        assert!(p.m2s.unwrap().carries_data());
        assert_eq!(p.wire_bytes, HEADER_BYTES + DATA_BYTES);
    }

    #[test]
    fn writeback_also_crosses_as_memwr() {
        let p = packetize(&req(MemCmd::WritebackDirty), 1).unwrap();
        assert_eq!(p.channel, Channel::M2SRwD);
    }

    #[test]
    fn coherence_traffic_stays_local() {
        assert!(packetize(&req(MemCmd::InvalidateReq), 0).is_none());
        assert!(packetize(&req(MemCmd::UpgradeReq), 0).is_none());
    }

    #[test]
    fn read_response_is_drs_with_data() {
        let p = packetize(&req(MemCmd::ReadReq), 9).unwrap();
        let r = make_response(&p);
        assert_eq!(r.channel, Channel::S2MDrs);
        assert_eq!(r.s2m, Some(S2MOpcode::MemData));
        assert_eq!(r.wire_bytes, HEADER_BYTES + DATA_BYTES);
        assert_eq!(r.tag, 9);
    }

    #[test]
    fn write_response_is_ndr_cmp() {
        let p = packetize(&req(MemCmd::WriteReq), 2).unwrap();
        let r = make_response(&p);
        assert_eq!(r.channel, Channel::S2MNdr);
        assert_eq!(r.s2m, Some(S2MOpcode::Cmp));
        assert!(!r.s2m.unwrap().carries_data());
    }

    #[test]
    fn rfo_is_header_only_and_its_grant_carries_the_line() {
        let p = packetize_rfo(&req(MemCmd::WriteReq), 4);
        assert_eq!(p.channel, Channel::M2SReq);
        assert_eq!(p.m2s, Some(M2SOpcode::MemInv));
        assert_eq!(p.wire_bytes, HEADER_BYTES);
        let r = make_response(&p);
        assert_eq!(r.channel, Channel::S2MDrs);
        assert_eq!(r.s2m, Some(S2MOpcode::MemData));
        assert_eq!(r.tag, 4);
    }

    #[test]
    fn bi_snoop_and_response_wire_shapes() {
        let snp = make_bi_snoop(0x2000, 7, 11);
        assert_eq!(snp.channel, Channel::S2MBISnp);
        assert_eq!(snp.s2m, Some(S2MOpcode::BISnpInv));
        assert!(!snp.s2m.unwrap().carries_data());
        assert_eq!(snp.wire_bytes, HEADER_BYTES);
        let clean = make_bi_response(0x2000, 7, 11, false);
        assert_eq!(clean.channel, Channel::M2SBIRsp);
        assert_eq!(clean.wire_bytes, HEADER_BYTES);
        let dirty = make_bi_response(0x2000, 7, 11, true);
        assert_eq!(dirty.wire_bytes, HEADER_BYTES + DATA_BYTES);
    }

    #[test]
    fn depacketize_extracts_media_op() {
        let p = packetize(&req(MemCmd::WriteReq), 0).unwrap();
        assert_eq!(depacketize(&p), (true, 0x1000));
        let p = packetize(&req(MemCmd::ReadReq), 0).unwrap();
        assert_eq!(depacketize(&p), (false, 0x1000));
    }
}
