//! Virtual CXL switch: one upstream port to a root port, fanning out
//! to multiple Type-3 endpoints on its downstream ports.
//!
//! The timing model keeps the tree's two contention points explicit:
//!
//! * the **upstream link** is a single [`CxlLink`] — wire occupancy and
//!   the M2S request-credit pool are shared by *every* endpoint behind
//!   the switch, so a hot neighbour steals both bandwidth and credits
//!   (the back-pressure a pooled fabric really exhibits);
//! * each hop through the switch pays a fixed **store-and-forward
//!   latency** (`fwd_lat_ns`), in both directions.
//!
//! Downstream (switch -> endpoint) links live in the root complex's
//! per-device link table and are traversed uncredited
//! ([`CxlLink::forward_m2s`]): flow control lives at the shared
//! upstream port, as in a credit-per-vPPB CXL 2.0 switch collapsed to
//! its first-order effect.

use crate::sim::{ns_to_ticks, Tick};
use crate::stats::{Counter, StatDump};

use super::link::CxlLink;
use super::mem_proto::CxlMemPacket;

/// Forwarding counters of one switch (per direction).
#[derive(Clone, Debug, Default)]
pub struct SwitchStats {
    pub m2s_forwarded: Counter,
    pub s2m_forwarded: Counter,
}

/// Timing model of one virtual switch.
pub struct CxlSwitch {
    /// The shared upstream link (root port <-> upstream switch port).
    pub us_link: CxlLink,
    fwd_ticks: Tick,
    /// Device indices attached to the downstream ports, in port order.
    pub devices: Vec<usize>,
    pub stats: SwitchStats,
}

impl CxlSwitch {
    pub fn new(
        link_lat_ns: f64,
        link_bw_gbps: f64,
        fwd_lat_ns: f64,
        flit_bytes: u64,
        credits: usize,
        devices: Vec<usize>,
    ) -> Self {
        CxlSwitch {
            us_link: CxlLink::new(
                link_lat_ns,
                link_bw_gbps,
                flit_bytes,
                credits,
            ),
            fwd_ticks: ns_to_ticks(fwd_lat_ns),
            devices,
            stats: SwitchStats::default(),
        }
    }

    /// M2S hop: consume an upstream credit, cross the upstream wire,
    /// pay the forwarding latency. The caller has confirmed credit
    /// availability on [`CxlSwitch::us_link`]. Returns the tick the
    /// packet reaches the downstream port.
    pub fn forward_m2s(&mut self, now: Tick, pkt: &CxlMemPacket) -> Tick {
        self.stats.m2s_forwarded.inc();
        self.us_link.send_m2s(now, pkt) + self.fwd_ticks
    }

    /// M2S hop on the dedicated uncredited BI channel: a BIRsp answers
    /// a device-initiated snoop, so it must never wait on the request
    /// credits its sender may itself be blocking. Same wire + forward
    /// cost as [`CxlSwitch::forward_m2s`], no credit consumed.
    pub fn forward_m2s_uncredited(
        &mut self,
        now: Tick,
        pkt: &CxlMemPacket,
    ) -> Tick {
        self.stats.m2s_forwarded.inc();
        self.us_link.forward_m2s(now, pkt) + self.fwd_ticks
    }

    /// S2M hop: pay the forwarding latency, then cross the upstream
    /// wire toward the root complex. Returns the RC arrival tick.
    pub fn forward_s2m(&mut self, now: Tick, pkt: &CxlMemPacket) -> Tick {
        self.stats.s2m_forwarded.inc();
        self.us_link.send_s2m(now + self.fwd_ticks, pkt)
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(
            &format!("{path}.m2s_forwarded"),
            &self.stats.m2s_forwarded,
        );
        d.counter(
            &format!("{path}.s2m_forwarded"),
            &self.stats.s2m_forwarded,
        );
        self.us_link.dump(&format!("{path}.us_link"), d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::mem_proto::{self};
    use crate::sim::{MemCmd, Packet};

    fn pkt(id: u64) -> CxlMemPacket {
        mem_proto::packetize(
            &Packet::new(id, MemCmd::ReadReq, 0x1000, 64, 0, 0),
            id as u16,
        )
        .unwrap()
    }

    #[test]
    fn m2s_adds_wire_and_forwarding_latency() {
        let mut sw = CxlSwitch::new(20.0, 32.0, 25.0, 68, 4, vec![0, 1]);
        let at_dsp = sw.forward_m2s(0, &pkt(1));
        // 68 B @ 32 GB/s = 2.125 ns + 20 ns wire + 25 ns forward.
        assert_eq!(at_dsp, 2125 + 20_000 + 25_000);
        assert_eq!(sw.stats.m2s_forwarded.get(), 1);
        assert_eq!(sw.us_link.credits_in_use(), 1);
    }

    #[test]
    fn shared_credit_pool_back_pressures_all_ports() {
        use crate::cxl::link::CreditAvail;
        let mut sw = CxlSwitch::new(20.0, 32.0, 25.0, 68, 1, vec![0, 1]);
        sw.forward_m2s(0, &pkt(1));
        // Either endpoint asking next is stalled on the same pool; the
        // in-flight credit has no timed retirement yet, so the pool
        // answers Unknown (bounded re-probe).
        assert_eq!(
            sw.us_link.credit_available_at(100),
            CreditAvail::Unknown
        );
        sw.us_link.retire(60_000);
        assert_eq!(
            sw.us_link.credit_available_at(100),
            CreditAvail::RetiresAt(60_000)
        );
    }

    #[test]
    fn s2m_pays_forwarding_before_the_wire() {
        let mut sw = CxlSwitch::new(20.0, 32.0, 25.0, 68, 4, vec![0]);
        let p = pkt(1);
        let resp = mem_proto::make_response(&p);
        let at_rc = sw.forward_s2m(0, &resp);
        // forward 25 ns + DRS 2 flits (136 B -> 4.25 ns) + 20 ns wire.
        assert_eq!(at_rc, 25_000 + 4250 + 20_000);
        assert_eq!(sw.stats.s2m_forwarded.get(), 1);
    }
}
