//! CXL Root Complex — the host-side protocol entity (paper Fig. 1B/4).
//!
//! Sits on the I/O bus. Converts host load/store packets targeting a
//! committed HDM range into CXL.mem M2S packets (**packetization**, with
//! its configurable latency), drives them through the credit-controlled
//! link, and converts S2M responses back. Also owns the RC-side DVSEC
//! surface (Set 1 of Fig. 3) that the guest driver binds against.

use crate::config::CxlConfig;
use crate::sim::{ns_to_ticks, Packet, Tick};
use crate::stats::{Counter, Histogram, StatDump};

use super::link::CxlLink;
use super::mem_proto::{self, CxlMemPacket};

#[derive(Clone, Debug, Default)]
pub struct RcStats {
    pub packetized: Counter,
    pub responses: Counter,
    pub packetize_ticks: Counter,
    pub round_trip: Histogram,
}

pub struct CxlRootComplex {
    pkt_ticks: Tick,
    depkt_ticks: Tick,
    pub link: CxlLink,
    next_tag: u16,
    pub stats: RcStats,
    /// Host address ranges routed to the expander (mirrors the committed
    /// HDM decoders; programmed by the guest driver via
    /// [`set_hdm_range`]).
    hdm_ranges: Vec<(u64, u64)>,
}

impl CxlRootComplex {
    pub fn new(cfg: &CxlConfig) -> Self {
        CxlRootComplex {
            pkt_ticks: ns_to_ticks(cfg.pkt_lat_ns),
            depkt_ticks: ns_to_ticks(cfg.depkt_lat_ns),
            link: CxlLink::new(
                cfg.link_lat_ns,
                cfg.link_bw_gbps,
                cfg.flit_bytes,
                cfg.credits,
            ),
            next_tag: 0,
            stats: RcStats::default(),
            hdm_ranges: Vec::new(),
        }
    }

    /// Driver hook: HDM decoder committed on the device — mirror the
    /// routing window here (real RCs snoop the same programming).
    pub fn set_hdm_range(&mut self, base: u64, size: u64) {
        self.hdm_ranges.push((base, size));
    }

    pub fn routes(&self, addr: u64) -> bool {
        self.hdm_ranges
            .iter()
            .any(|&(b, s)| addr >= b && addr < b + s)
    }

    pub fn hdm_ranges(&self) -> &[(u64, u64)] {
        &self.hdm_ranges
    }

    /// Packetize a host request at `now`. Returns:
    /// * `Ok((pkt, device_arrival))` — entered the link.
    /// * `Err(retry_at)` — no M2S credit; retry at the given tick.
    pub fn packetize_and_send(
        &mut self,
        now: Tick,
        host_pkt: &Packet,
    ) -> Result<(CxlMemPacket, Tick), Tick> {
        let after_pkt = now + self.pkt_ticks;
        match self.link.credit_available_at(after_pkt) {
            Some(t) if t <= after_pkt => {}
            Some(t) => {
                self.link.note_credit_stall(after_pkt, t);
                return Err(t);
            }
            None => panic!("zero-credit link"),
        }
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        let pkt = mem_proto::packetize(host_pkt, tag)
            .expect("unroutable command reached the RC");
        self.stats.packetized.inc();
        self.stats.packetize_ticks.add(self.pkt_ticks);
        let arrival = self.link.send_m2s(after_pkt, &pkt);
        Ok((pkt, arrival))
    }

    /// The device's S2M response enters the link at `ready`; returns the
    /// tick at which the host-side response is available (after link +
    /// RC-side de-packetization).
    pub fn receive_s2m(
        &mut self,
        ready: Tick,
        resp: &CxlMemPacket,
        issued_at: Tick,
    ) -> Tick {
        let rc_arrival = self.link.send_s2m(ready, resp);
        let done = rc_arrival + self.depkt_ticks; // RC-side unpack
        self.link.retire(done);
        self.stats.responses.inc();
        self.stats.round_trip.sample(done.saturating_sub(issued_at));
        done
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.packetized"), &self.stats.packetized);
        d.counter(&format!("{path}.responses"), &self.stats.responses);
        d.hist(&format!("{path}.round_trip"), &self.stats.round_trip);
        self.link.dump(&format!("{path}.link"), d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::MemCmd;

    fn rc() -> CxlRootComplex {
        let mut r = CxlRootComplex::new(&SimConfig::default().cxl);
        r.set_hdm_range(2 << 30, 4 << 30);
        r
    }

    fn pkt(cmd: MemCmd) -> Packet {
        Packet::new(1, cmd, 2 << 30, 64, 0, 0)
    }

    #[test]
    fn routing_window() {
        let r = rc();
        assert!(r.routes(2 << 30));
        assert!(r.routes((6u64 << 30) - 64));
        assert!(!r.routes(6 << 30));
        assert!(!r.routes(0x1000));
    }

    #[test]
    fn packetize_adds_latency_and_tags() {
        let mut r = rc();
        let (p1, a1) = r.packetize_and_send(0, &pkt(MemCmd::ReadReq)).unwrap();
        let (p2, _) = r.packetize_and_send(0, &pkt(MemCmd::ReadReq)).unwrap();
        assert_ne!(p1.tag, p2.tag);
        // pkt_lat 25ns + ser (68B @ 32GB/s = 2.125ns) + link 20ns.
        assert_eq!(a1, ns_to_ticks(25.0) + 2125 + ns_to_ticks(20.0));
    }

    #[test]
    fn credit_exhaustion_surfaces_retry_tick() {
        let mut cfg = SimConfig::default().cxl;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        r.set_hdm_range(0, 4 << 30);
        let (p, arr) = r
            .packetize_and_send(0, &pkt(MemCmd::ReadReq))
            .unwrap();
        // Second request has no credit.
        let e = r.packetize_and_send(0, &pkt(MemCmd::ReadReq));
        assert!(e.is_err());
        // Retire the first: response path frees the credit.
        let resp = mem_proto::make_response(&p);
        let done = r.receive_s2m(arr + 100, &resp, 0);
        let retry = r.packetize_and_send(done, &pkt(MemCmd::ReadReq));
        assert!(retry.is_ok());
        assert_eq!(r.link.stats.credit_stalls.get(), 1);
    }

    #[test]
    fn round_trip_recorded() {
        let mut r = rc();
        let (p, arr) = r.packetize_and_send(0, &pkt(MemCmd::WriteReq)).unwrap();
        let resp = mem_proto::make_response(&p);
        let done = r.receive_s2m(arr + 50_000, &resp, 0);
        assert!(done > arr);
        assert_eq!(r.stats.round_trip.count(), 1);
        assert!(r.stats.round_trip.stats.mean() >= done as f64 * 0.9);
    }
}
