//! CXL Root Complex — the host-side protocol entity (paper Fig. 1B/4).
//!
//! Sits on one host's I/O bus. Converts that host's load/store packets
//! targeting a committed HDM window into CXL.mem M2S packets
//! (**packetization**, with its configurable latency), drives them into
//! the shared [`super::Fabric`] (per-device credit-controlled links,
//! switch hops), and converts S2M responses back. The **interleave
//! decoder** lives here: each window carries the CFMWS interleave
//! parameters (ways, granularity, modulo/XOR arithmetic) and every line
//! address resolves to exactly one target device. Also owns the RC-side
//! DVSEC surface (Set 1 of Fig. 3) that the guest driver binds against.
//!
//! One `CxlRootComplex` exists per simulated host; the links, switches
//! and devices they all talk to live in the fabric — that split is what
//! makes multi-host pooling contention observable.

use crate::config::CxlConfig;
use crate::sim::{ns_to_ticks, Packet, Tick};
use crate::stats::{Counter, Histogram, StatDump};

use super::fabric::Fabric;
use super::mem_proto::{self, CxlMemPacket};

#[derive(Clone, Debug, Default)]
pub struct RcStats {
    pub packetized: Counter,
    pub responses: Counter,
    pub packetize_ticks: Counter,
    pub round_trip: Histogram,
}

/// One committed routing window with its interleave decode parameters
/// (mirrors a CFMWS + the committed host-bridge decoders beneath it).
#[derive(Clone, Debug)]
pub struct HdmWindow {
    pub base: u64,
    pub size: u64,
    /// Interleave granularity in bytes (power of two).
    pub granularity: u64,
    /// Device indices in CFMWS target-slot order (len = ways). Shared
    /// (`Arc`) because every host mirroring the same window definition
    /// carries the same list — mirroring clones a pointer, not a `Vec`.
    pub targets: std::sync::Arc<[usize]>,
    /// XOR target-selection arithmetic instead of modulo.
    pub xor: bool,
    /// Device-physical base the window maps onto (mirrors the endpoint
    /// decoder's DPA skip): non-zero for the upper LD slices of an MLD.
    pub dpa_base: u64,
}

impl HdmWindow {
    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.base && addr < self.base + self.size
    }

    /// CFMWS target slot for `addr`. Modulo: the granule index mod
    /// ways. XOR: successive log2(ways)-bit fields of the granule index
    /// folded together — decorrelates strided streams from the target
    /// selection (both arithmetics are CXL 2.0 CFMWS options).
    pub fn slot(&self, addr: u64) -> usize {
        let ways = self.targets.len() as u64;
        if ways == 1 {
            return 0;
        }
        let chunk = (addr - self.base) / self.granularity;
        if self.xor {
            let bits = ways.trailing_zeros();
            let mut c = chunk;
            let mut s = 0u64;
            while c != 0 {
                s ^= c & (ways - 1);
                c >>= bits;
            }
            s as usize
        } else {
            (chunk % ways) as usize
        }
    }

    /// The device index owning `addr`.
    pub fn target(&self, addr: u64) -> usize {
        self.targets[self.slot(addr)]
    }

    /// Strip the interleave bits: window-relative HPA -> device DPA
    /// (offset into the window's LD slice via `dpa_base`). Valid for
    /// modulo arithmetic; XOR permutes targets within each ways-sized
    /// granule group, so the dense packing is identical.
    pub fn dpa(&self, addr: u64) -> u64 {
        let off = addr - self.base;
        let ways = self.targets.len() as u64;
        if ways == 1 {
            return self.dpa_base + off;
        }
        self.dpa_base
            + (off / (self.granularity * ways)) * self.granularity
            + off % self.granularity
    }
}

pub struct CxlRootComplex {
    pkt_ticks: Tick,
    depkt_ticks: Tick,
    /// Fabric device count, for window-target validation.
    ndev: usize,
    next_tag: u16,
    pub stats: RcStats,
    /// Committed HDM windows (mirrors the host-bridge decoders;
    /// programmed by the guest driver via [`CxlRootComplex::add_window`]
    /// / [`CxlRootComplex::set_hdm_range`]).
    windows: Vec<HdmWindow>,
}

impl CxlRootComplex {
    pub fn new(cfg: &CxlConfig) -> Self {
        CxlRootComplex {
            pkt_ticks: ns_to_ticks(cfg.pkt_lat_ns),
            depkt_ticks: ns_to_ticks(cfg.depkt_lat_ns),
            ndev: cfg.devices.max(1),
            next_tag: 0,
            stats: RcStats::default(),
            windows: Vec::new(),
        }
    }

    /// Driver hook: HDM decoder committed on the device — mirror the
    /// routing window here (real RCs snoop the same programming). The
    /// single-target convenience form routes everything to device 0.
    pub fn set_hdm_range(&mut self, base: u64, size: u64) {
        self.add_window(HdmWindow {
            base,
            size,
            granularity: 256,
            targets: vec![0].into(),
            xor: false,
            dpa_base: 0,
        });
    }

    /// Mirror a committed interleave-set window.
    pub fn add_window(&mut self, w: HdmWindow) {
        assert!(w.targets.len().is_power_of_two());
        assert!(w.granularity.is_power_of_two() && w.granularity >= 256);
        assert!(
            w.targets.iter().all(|&t| t < self.ndev),
            "window targets a device outside the fabric"
        );
        self.windows.push(w);
    }

    /// Hot-remove hook: drop the routing window based at `base` (the
    /// guest just uncommitted the matching host-bridge decoder). After
    /// this, no new request can be routed at the departing device;
    /// responses already timed stay valid. Returns whether a window
    /// was removed.
    pub fn remove_window(&mut self, base: u64) -> bool {
        let before = self.windows.len();
        self.windows.retain(|w| w.base != base);
        self.windows.len() != before
    }

    pub fn windows(&self) -> &[HdmWindow] {
        &self.windows
    }

    pub fn routes(&self, addr: u64) -> bool {
        self.windows.iter().any(|w| w.contains(addr))
    }

    /// Interleave decode: the device index owning `addr`.
    pub fn route(&self, addr: u64) -> Option<usize> {
        self.windows
            .iter()
            .find(|w| w.contains(addr))
            .map(|w| w.target(addr))
    }

    /// Decode to `(device, device-physical address)` in one step — the
    /// baseline membus path uses this where no protocol flows.
    pub fn route_dpa(&self, addr: u64) -> Option<(usize, u64)> {
        self.windows
            .iter()
            .find(|w| w.contains(addr))
            .map(|w| (w.target(addr), w.dpa(addr)))
    }

    pub fn hdm_ranges(&self) -> Vec<(u64, u64)> {
        self.windows.iter().map(|w| (w.base, w.size)).collect()
    }

    /// Packetize only: tag the request and account the packetization
    /// cost, without touching the fabric. The split-phase event loop
    /// uses this at emission time — the host builds the M2S packet when
    /// it *decides* to send, and the credit check + link send happen
    /// later at the fabric-commit barrier (see
    /// `system::machine`'s parallel determinism contract). Tags are
    /// therefore assigned in host event order, which is what makes them
    /// independent of worker-thread scheduling.
    pub fn packetize(&mut self, host_pkt: &Packet) -> CxlMemPacket {
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        let pkt = mem_proto::packetize(host_pkt, tag)
            .expect("unroutable command reached the RC");
        self.stats.packetized.inc();
        self.stats.packetize_ticks.add(self.pkt_ticks);
        pkt
    }

    /// Packetize a store to a BI-coherent shared line as an RFO (M2S
    /// Req + MemInv): same tag discipline and packetization cost as
    /// [`CxlRootComplex::packetize`], but the opcode tells the device's
    /// snoop filter to grant exclusivity and back-invalidate the other
    /// sharer hosts.
    pub fn packetize_rfo(&mut self, host_pkt: &Packet) -> CxlMemPacket {
        let tag = self.next_tag;
        self.next_tag = self.next_tag.wrapping_add(1);
        let pkt = mem_proto::packetize_rfo(host_pkt, tag);
        self.stats.packetized.inc();
        self.stats.packetize_ticks.add(self.pkt_ticks);
        pkt
    }

    /// Account a response that the fabric-commit phase already timed
    /// (`done` = RC-side availability, after link hops + depacketize).
    /// The stats-side half of [`CxlRootComplex::receive_s2m`].
    pub fn note_response(&mut self, done: Tick, issued_at: Tick) {
        self.stats.responses.inc();
        self.stats.round_trip.sample(done.saturating_sub(issued_at));
    }

    /// RC-side packetization cost in ticks.
    pub fn pkt_ticks(&self) -> Tick {
        self.pkt_ticks
    }

    /// RC-side de-packetization cost in ticks.
    pub fn depkt_ticks(&self) -> Tick {
        self.depkt_ticks
    }

    /// Packetize a host request at `now` onto device `dev`'s fabric
    /// path:
    /// * `Ok((pkt, device_arrival))` — entered the link(s).
    /// * `Err(retry_at)` — no M2S credit; retry at the given tick.
    ///
    /// For a direct-attached device the credit pool is its root-port
    /// link; behind a switch it is the switch's *shared* upstream link,
    /// so siblings — including other hosts' traffic — contend for both
    /// credits and upstream wire time.
    pub fn packetize_and_send(
        &mut self,
        fabric: &mut Fabric,
        now: Tick,
        host_pkt: &Packet,
        dev: usize,
    ) -> Result<(CxlMemPacket, Tick), Tick> {
        let after_pkt = now + self.pkt_ticks;
        let credit_link = fabric.credit_link(dev);
        match credit_link.credit_available_at(after_pkt) {
            super::link::CreditAvail::Now => {}
            super::link::CreditAvail::RetiresAt(t) => {
                credit_link.note_credit_stall(after_pkt, t);
                return Err(t);
            }
            super::link::CreditAvail::Unknown => {
                // Every in-flight credit is an unretired placeholder:
                // no timed retirement to wait on, so re-probe after a
                // bounded link-determined interval (never a Tick::MAX
                // park, which would strand the request and poison the
                // credit_wait histogram).
                let t = credit_link.reprobe_at(after_pkt);
                credit_link.note_credit_stall(after_pkt, t);
                return Err(t);
            }
        }
        let pkt = self.packetize(host_pkt);
        let arrival = fabric.send_m2s(after_pkt, &pkt, dev);
        Ok((pkt, arrival))
    }

    /// Device `dev`'s S2M response enters its leaf link at `ready`;
    /// returns the tick at which the host-side response is available
    /// (after the path's link hops + RC-side de-packetization).
    pub fn receive_s2m(
        &mut self,
        fabric: &mut Fabric,
        ready: Tick,
        resp: &CxlMemPacket,
        issued_at: Tick,
        dev: usize,
    ) -> Tick {
        let rc_arrival = fabric.send_s2m(ready, resp, dev);
        let done = rc_arrival + self.depkt_ticks; // RC-side unpack
        fabric.retire(dev, done);
        self.note_response(done, issued_at);
        done
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.packetized"), &self.stats.packetized);
        d.counter(&format!("{path}.responses"), &self.stats.responses);
        d.hist(&format!("{path}.round_trip"), &self.stats.round_trip);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::MemCmd;

    fn rc_fab() -> (CxlRootComplex, Fabric) {
        let cfg = SimConfig::default().cxl;
        let mut r = CxlRootComplex::new(&cfg);
        r.set_hdm_range(2 << 30, 4 << 30);
        (r, Fabric::new(&cfg))
    }

    fn pkt(cmd: MemCmd) -> Packet {
        Packet::new(1, cmd, 2 << 30, 64, 0, 0)
    }

    #[test]
    fn routing_window() {
        let (r, _) = rc_fab();
        assert!(r.routes(2 << 30));
        assert!(r.routes((6u64 << 30) - 64));
        assert!(!r.routes(6 << 30));
        assert!(!r.routes(0x1000));
        assert_eq!(r.route(2 << 30), Some(0));
    }

    #[test]
    fn packetize_adds_latency_and_tags() {
        let (mut r, mut f) = rc_fab();
        let (p1, a1) = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        let (p2, _) = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        assert_ne!(p1.tag, p2.tag);
        // pkt_lat 25ns + ser (68B @ 32GB/s = 2.125ns) + link 20ns.
        assert_eq!(a1, ns_to_ticks(25.0) + 2125 + ns_to_ticks(20.0));
    }

    #[test]
    fn credit_exhaustion_surfaces_retry_tick() {
        let mut cfg = SimConfig::default().cxl;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        r.set_hdm_range(0, 4 << 30);
        let (p, arr) = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        // Second request has no credit.
        let e = r.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0);
        assert!(e.is_err());
        // Retire the first: response path frees the credit.
        let resp = mem_proto::make_response(&p);
        let done = r.receive_s2m(&mut f, arr + 100, &resp, 0, 0);
        let retry =
            r.packetize_and_send(&mut f, done, &pkt(MemCmd::ReadReq), 0);
        assert!(retry.is_ok());
        assert_eq!(f.links[0].stats.credit_stalls.get(), 1);
    }

    #[test]
    fn unretired_credit_pool_yields_bounded_retry() {
        // The only credit is held by a request whose response has not
        // been timed yet (placeholder unretired): the retry tick must
        // be a bounded re-probe, not a Tick::MAX park, and the
        // credit_wait histogram must not swallow a sentinel sample.
        let mut cfg = SimConfig::default().cxl;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        r.set_hdm_range(0, 4 << 30);
        r.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        let retry = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap_err();
        assert!(
            retry < ns_to_ticks(1_000.0),
            "bounded re-probe expected, got {retry}"
        );
        let cw = &f.links[0].stats.credit_wait;
        assert_eq!(cw.count(), 1);
        assert!(
            cw.stats.max < ns_to_ticks(1_000.0) as f64,
            "credit_wait poisoned: {}",
            cw.stats.max
        );
    }

    #[test]
    fn round_trip_recorded() {
        let (mut r, mut f) = rc_fab();
        let (p, arr) = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::WriteReq), 0)
            .unwrap();
        let resp = mem_proto::make_response(&p);
        let done = r.receive_s2m(&mut f, arr + 50_000, &resp, 0, 0);
        assert!(done > arr);
        assert_eq!(r.stats.round_trip.count(), 1);
        assert!(r.stats.round_trip.stats.mean() >= done as f64 * 0.9);
    }

    #[test]
    fn per_device_links_are_independent() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 2;
        cfg.interleave_ways = 1;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        assert_eq!(f.links.len(), 2);
        r.add_window(HdmWindow {
            base: 4 << 30,
            size: 8 << 30,
            granularity: 256,
            targets: vec![0, 1].into(),
            xor: false,
            dpa_base: 0,
        });
        // Exhausting device 0's credit leaves device 1 usable.
        r.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0).unwrap();
        assert!(r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .is_err());
        assert!(r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 1)
            .is_ok());
    }

    #[test]
    fn switched_path_adds_hops_and_shares_credits() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 2;
        cfg.interleave_ways = 1;
        cfg.switches = 1;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        assert_eq!(f.switches.len(), 1);
        assert_eq!(f.switches[0].devices, vec![0, 1]);
        r.add_window(HdmWindow {
            base: 4 << 30,
            size: 4 << 30,
            granularity: 256,
            targets: vec![0].into(),
            xor: false,
            dpa_base: 0,
        });
        let (p, arr) = r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        // Direct default: pkt 25 ns + ser 2.125 + link 20 ns. Switched
        // adds the upstream hop (ser 2.125 + 20 ns) and 25 ns forward.
        let direct = ns_to_ticks(25.0) + 2125 + ns_to_ticks(20.0);
        assert_eq!(arr, direct + 2125 + ns_to_ticks(20.0 + 25.0));
        // The shared upstream pool back-pressures the *sibling* device.
        let e = r.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 1);
        assert!(e.is_err(), "sibling must stall on the shared credit");
        assert_eq!(f.switches[0].us_link.stats.credit_stalls.get(), 1);
        // Retiring the first response frees the pool for the sibling.
        let resp = mem_proto::make_response(&p);
        let done = r.receive_s2m(&mut f, arr + 100, &resp, 0, 0);
        assert!(r
            .packetize_and_send(&mut f, done, &pkt(MemCmd::ReadReq), 1)
            .is_ok());
    }

    #[test]
    fn two_hosts_contend_on_one_shared_upstream_pool() {
        // Two root complexes (two hosts) over ONE fabric: host B stalls
        // on the credit host A consumed — the cross-host back-pressure
        // that motivates the host/fabric split.
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 2;
        cfg.interleave_ways = 1;
        cfg.switches = 1;
        cfg.credits = 1;
        let mut ra = CxlRootComplex::new(&cfg);
        let mut rb = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        let (p, arr) = ra
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0)
            .unwrap();
        let e = rb.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 1);
        assert!(e.is_err(), "host B must stall on host A's credit");
        let resp = mem_proto::make_response(&p);
        let done = ra.receive_s2m(&mut f, arr + 100, &resp, 0, 0);
        assert!(rb
            .packetize_and_send(&mut f, done, &pkt(MemCmd::ReadReq), 1)
            .is_ok());
    }

    #[test]
    fn direct_devices_keep_independent_credit_pools() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 2;
        cfg.interleave_ways = 1;
        cfg.credits = 1;
        let mut r = CxlRootComplex::new(&cfg);
        let mut f = Fabric::new(&cfg);
        assert!(f.switches.is_empty());
        r.packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 0).unwrap();
        // Without a switch, device 1's pool is untouched.
        assert!(r
            .packetize_and_send(&mut f, 0, &pkt(MemCmd::ReadReq), 1)
            .is_ok());
    }

    #[test]
    fn modulo_interleave_alternates_targets() {
        let w = HdmWindow {
            base: 4 << 30,
            size: 8 << 30,
            granularity: 1024,
            targets: vec![0, 1].into(),
            xor: false,
            dpa_base: 0,
        };
        let b = 4u64 << 30;
        assert_eq!(w.target(b), 0);
        assert_eq!(w.target(b + 1023), 0);
        assert_eq!(w.target(b + 1024), 1);
        assert_eq!(w.target(b + 2048), 0);
        // DPA packs densely per device.
        assert_eq!(w.dpa(b), 0);
        assert_eq!(w.dpa(b + 1024), 0);
        assert_eq!(w.dpa(b + 2048), 1024);
        assert_eq!(w.dpa(b + 2048 + 7), 1024 + 7);
    }

    #[test]
    fn xor_interleave_covers_all_targets() {
        let w = HdmWindow {
            base: 0,
            size: 1 << 20,
            granularity: 256,
            targets: vec![0, 1, 2, 3].into(),
            xor: true,
            dpa_base: 0,
        };
        let mut seen = [0u64; 4];
        for line in (0..(1u64 << 20)).step_by(256) {
            seen[w.slot(line)] += 1;
        }
        // Perfectly balanced across the 4 targets.
        assert!(seen.iter().all(|&c| c == seen[0]), "{seen:?}");
    }
}
