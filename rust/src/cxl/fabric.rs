//! The shared CXL fabric: everything *below* the hosts' root complexes.
//!
//! A fabric owns the expander devices (SLDs and MLDs), the virtual
//! switches, and the leaf links that connect them — the hardware that is
//! physically shared when several simulated hosts pool the same MLD.
//! Host-side state (HDM routing windows, packetizer, tags) stays in each
//! host's [`super::CxlRootComplex`]; the fabric is where their traffic
//! meets, so cross-host contention on a switch's upstream link or an
//! MLD's media falls out of the shared occupancy state.
//!
//! The fabric also plays **Fabric Manager**: logical-device ownership is
//! established by driving the FM-API bind commands through each device's
//! real mailbox register surface ([`Fabric::bind_from_config`]), exactly
//! the state the guests later query with Get LD Allocations.
//!
//! Ownership is not fixed at boot: an `[fm] events` schedule makes the
//! FM re-bind logical devices **at runtime** ([`Fabric::fm_unbind`] /
//! [`Fabric::fm_bind`]). Each action goes through the same mailbox
//! command the boot path uses, and the affected host is told via an
//! Event-Log record ([`Fabric::post_fm_event`]) that its driver drains
//! with `GET_EVENT_RECORDS` — the machine's FM event handler
//! (`system::Machine`) sequences quiesce → notify → unbind so packets
//! to a departing LD complete (or retry) deterministically first.

use anyhow::{bail, Result};

use crate::config::CxlConfig;
use crate::sim::Tick;
use crate::stats::StatDump;

use super::device::CxlDevice;
use super::link::{CxlLink, LinkStats};
use super::mailbox::{opcode, retcode, EventRecord};
use super::mem_proto::CxlMemPacket;
use super::switch::CxlSwitch;

pub struct Fabric {
    /// One leaf link per expander device: the root-port link when the
    /// device is direct-attached, the switch downstream-port link when
    /// it sits behind a switch.
    pub links: Vec<CxlLink>,
    /// Virtual switches between root ports and endpoints.
    pub switches: Vec<CxlSwitch>,
    /// Route table: the switch (if any) on device i's path. Routing is
    /// by hierarchy — flow control and the extra hops follow this
    /// table, not a flat device index.
    dev_switch: Vec<Option<usize>>,
    /// Expander device models, in config order.
    pub devices: Vec<CxlDevice>,
}

impl Fabric {
    pub fn new(cfg: &CxlConfig) -> Self {
        let links = (0..cfg.devices.max(1))
            .map(|i| {
                let d = cfg.device(i);
                CxlLink::new(
                    d.link_lat_ns,
                    d.link_bw_gbps,
                    cfg.flit_bytes,
                    cfg.credits,
                )
            })
            .collect();
        let switches = (0..cfg.switches)
            .map(|j| {
                let s = cfg.switch(j);
                CxlSwitch::new(
                    s.link_lat_ns,
                    s.link_bw_gbps,
                    s.fwd_lat_ns,
                    cfg.flit_bytes,
                    cfg.credits,
                    (s.first_dev..s.first_dev + s.ndev).collect(),
                )
            })
            .collect();
        let dev_switch =
            (0..cfg.devices.max(1)).map(|i| cfg.switch_of(i)).collect();
        let devices = (0..cfg.devices.max(1))
            .map(|i| CxlDevice::new_at(cfg, i, 0xC0FFEE + i as u64))
            .collect();
        Fabric { links, switches, dev_switch, devices }
    }

    /// Number of expander devices on the fabric.
    pub fn ndev(&self) -> usize {
        self.devices.len()
    }

    /// The credit pool governing M2S flow control toward device `dev`:
    /// its private root-port link when direct-attached, the *shared*
    /// upstream link of its switch otherwise (so siblings — and other
    /// hosts — back-pressure each other).
    pub fn credit_link(&mut self, dev: usize) -> &mut CxlLink {
        match self.dev_switch[dev] {
            Some(s) => &mut self.switches[s].us_link,
            None => &mut self.links[dev],
        }
    }

    /// Carry an M2S packet from a root port down to device `dev`'s
    /// endpoint; returns the endpoint arrival tick. The caller has
    /// confirmed (and thereby consumed) a credit on
    /// [`Fabric::credit_link`].
    pub fn send_m2s(
        &mut self,
        at: Tick,
        pkt: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        match self.dev_switch[dev] {
            None => self.links[dev].send_m2s(at, pkt),
            Some(s) => {
                // Upstream hop (consumes the shared credit), then the
                // uncredited downstream hop to the endpoint.
                let at_dsp = self.switches[s].forward_m2s(at, pkt);
                self.links[dev].forward_m2s(at_dsp, pkt)
            }
        }
    }

    /// Carry device `dev`'s S2M response up to its root port; returns
    /// the root-complex arrival tick (before RC-side de-packetization).
    pub fn send_s2m(
        &mut self,
        ready: Tick,
        resp: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        match self.dev_switch[dev] {
            None => self.links[dev].send_s2m(ready, resp),
            Some(s) => {
                let at_sw = self.links[dev].send_s2m(ready, resp);
                self.switches[s].forward_s2m(at_sw, resp)
            }
        }
    }

    /// Carry a host's M2S BIRsp down to device `dev` on the dedicated
    /// uncredited BI channel (CXL 3.x): same path and wire costs as
    /// [`Fabric::send_m2s`], but no request credit is consumed — the
    /// snooped host may be stalled on those very credits, and its ack
    /// must still get through. Returns the endpoint arrival tick.
    pub fn send_birsp(
        &mut self,
        at: Tick,
        pkt: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        match self.dev_switch[dev] {
            None => self.links[dev].forward_m2s(at, pkt),
            Some(s) => {
                let at_dsp =
                    self.switches[s].forward_m2s_uncredited(at, pkt);
                self.links[dev].forward_m2s(at_dsp, pkt)
            }
        }
    }

    /// A response retired on the host side at `done`: free the credit on
    /// device `dev`'s flow-control pool.
    pub fn retire(&mut self, dev: usize, done: Tick) {
        self.credit_link(dev).retire(done);
    }

    /// Sum a per-link statistic across every device leaf link.
    pub fn agg_link(&self, f: impl Fn(&LinkStats) -> u64) -> u64 {
        self.links.iter().map(|l| f(&l.stats)).sum()
    }

    /// Every credit pool on the fabric, labeled for invariant-violation
    /// reports: each device's leaf link plus each switch's shared
    /// upstream link. Leaf links behind a switch are forward-only
    /// (their credit state never moves, so their conservation equation
    /// holds trivially) — enumerating them unconditionally gives the
    /// checker total coverage without double-counting a pool.
    pub fn pools(&self) -> Vec<(String, &CxlLink)> {
        let mut out: Vec<(String, &CxlLink)> = self
            .links
            .iter()
            .enumerate()
            .map(|(i, l)| (format!("link{i}"), l))
            .collect();
        for (j, sw) in self.switches.iter().enumerate() {
            out.push((format!("sw{j}.us"), &sw.us_link));
        }
        out
    }

    /// Commit-lane partition: contiguous, switch-credit-disjoint device
    /// ranges `[lo, hi)` covering `0..ndev` in order. Devices behind the
    /// same switch share its upstream credit pool, so every device a
    /// switch serves lands in one range (the ranges are the connected
    /// components of the "shares flow-control state" relation). Two
    /// lanes never touch the same link, switch, or device, which is what
    /// makes the `&mut`-disjoint views of [`Fabric::lane_views`] sound.
    pub fn lane_ranges(&self) -> Vec<(usize, usize)> {
        let n = self.ndev();
        // reach_hi[i]: one past the furthest device that shares credit
        // state with i through some switch (i + 1 when direct-attached).
        let mut reach_hi: Vec<usize> = (0..n).map(|i| i + 1).collect();
        for sw in &self.switches {
            let lo = sw.devices.iter().copied().min().unwrap_or(0);
            let hi =
                sw.devices.iter().copied().max().map_or(0, |m| m + 1);
            for r in reach_hi.iter_mut().take(hi).skip(lo) {
                *r = (*r).max(hi);
            }
        }
        let mut out = Vec::new();
        let mut i = 0;
        while i < n {
            let mut hi = reach_hi[i];
            let mut j = i + 1;
            while j < hi {
                hi = hi.max(reach_hi[j]);
                j += 1;
            }
            out.push((i, hi));
            i = hi;
        }
        out
    }

    /// Routing table from device index to its lane group in `ranges`
    /// (as produced by [`Fabric::lane_ranges`]). The commit scheduler
    /// snapshots this so it can distribute pending entries while lane
    /// views hold `&mut` borrows of the fabric interior.
    pub fn lane_of_dev(&self, ranges: &[(usize, usize)]) -> Vec<usize> {
        let mut map = vec![0usize; self.ndev()];
        for (g, &(lo, hi)) in ranges.iter().enumerate() {
            for m in map.iter_mut().take(hi).skip(lo) {
                *m = g;
            }
        }
        map
    }

    /// Split the fabric interior into one [`FabricLane`] per range:
    /// disjoint `&mut` views over links/devices (via `split_at_mut`)
    /// plus each switch handed to the lane owning its span. Lanes are
    /// `Send`, so worker threads can commit against them concurrently;
    /// the borrow checker guarantees no lane can reach another's state.
    /// `ranges` must come from [`Fabric::lane_ranges`] on this fabric.
    pub fn lane_views(
        &mut self,
        ranges: &[(usize, usize)],
    ) -> Vec<FabricLane<'_>> {
        let dev_switch = &self.dev_switch;
        let mut links = self.links.as_mut_slice();
        let mut devices = self.devices.as_mut_slice();
        // Hand each switch to the lane whose range covers its span
        // (lane_ranges guarantees exactly one does).
        let mut sw_by_lane: Vec<Vec<(usize, &mut CxlSwitch)>> =
            ranges.iter().map(|_| Vec::new()).collect();
        for (j, sw) in self.switches.iter_mut().enumerate() {
            let lo = sw.devices.iter().copied().min().unwrap_or(0);
            let lane = ranges
                .iter()
                .position(|&(a, b)| a <= lo && lo < b)
                .expect("switch span outside every lane range");
            sw_by_lane[lane].push((j, sw));
        }
        let mut out = Vec::with_capacity(ranges.len());
        let mut cursor = 0;
        for (&(lo, hi), switches) in ranges.iter().zip(sw_by_lane) {
            debug_assert_eq!(lo, cursor, "lane ranges must be contiguous");
            let (l, lrest) =
                std::mem::take(&mut links).split_at_mut(hi - lo);
            links = lrest;
            let (d, drest) =
                std::mem::take(&mut devices).split_at_mut(hi - lo);
            devices = drest;
            out.push(FabricLane { lo, links: l, switches, dev_switch, devices: d });
            cursor = hi;
        }
        out
    }

    /// Fabric-manager role: drive the FM-API `BIND_LD` command through
    /// every device's mailbox so each window definition's logical
    /// device(s) belong to the host(s) `window_sharers` assigns —
    /// exclusive mode for single-host (pooled) windows, shared mode
    /// once per sharer for CXL 3.x shared windows. The guests later
    /// read exactly this state back with `GET_LD_ALLOCATIONS`.
    pub fn bind_from_config(
        &mut self,
        cfg: &CxlConfig,
        window_sharers: &[Vec<usize>],
    ) -> Result<()> {
        let defs = cfg.window_defs();
        assert_eq!(defs.len(), window_sharers.len());
        for (def, sharers) in defs.iter().zip(window_sharers) {
            for &dev in &def.targets {
                if sharers.len() > 1 {
                    for &host in sharers {
                        let code =
                            self.fm_bind_shared(dev, def.ld, host as u16);
                        if code != retcode::SUCCESS {
                            bail!(
                                "FM BIND_LD (shared) dev{dev}.ld{} -> \
                                 host{host} failed with code {code:#x}",
                                def.ld
                            );
                        }
                    }
                } else {
                    let host = sharers.first().copied().unwrap_or(0);
                    let code = self.fm_bind(dev, def.ld, host as u16);
                    if code != retcode::SUCCESS {
                        bail!(
                            "FM BIND_LD dev{dev}.ld{} -> host{host} \
                             failed with code {code:#x}",
                            def.ld
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// FM-API `BIND_LD` on device `dev`: give logical device `ld` to
    /// `host`. Returns the mailbox return code (`retcode::BUSY` when
    /// the LD is still owned — ownership is exclusive).
    pub fn fm_bind(&mut self, dev: usize, ld: u16, host: u16) -> u16 {
        let mut payload = [0u8; 4];
        payload[0..2].copy_from_slice(&ld.to_le_bytes());
        payload[2..4].copy_from_slice(&host.to_le_bytes());
        self.devices[dev]
            .mailbox
            .run_command(opcode::BIND_LD, &payload)
            .0
    }

    /// FM-API `BIND_LD` in shared mode on device `dev`: add `host` to
    /// logical device `ld`'s sharer set (CXL 3.x sharing). Fails BUSY
    /// when the LD is exclusively owned.
    pub fn fm_bind_shared(
        &mut self,
        dev: usize,
        ld: u16,
        host: u16,
    ) -> u16 {
        let mut payload = [0u8; 5];
        payload[0..2].copy_from_slice(&ld.to_le_bytes());
        payload[2..4].copy_from_slice(&host.to_le_bytes());
        payload[4] = super::mailbox::BIND_MODE_SHARED;
        self.devices[dev]
            .mailbox
            .run_command(opcode::BIND_LD, &payload)
            .0
    }

    /// FM-API `UNBIND_LD` on device `dev`: release logical device `ld`.
    /// Returns the mailbox return code.
    pub fn fm_unbind(&mut self, dev: usize, ld: u16) -> u16 {
        self.devices[dev]
            .mailbox
            .run_command(opcode::UNBIND_LD, &ld.to_le_bytes())
            .0
    }

    /// Current owner of `dev`'s logical device `ld`
    /// ([`super::mailbox::UNBOUND`] when unassigned).
    pub fn ld_owner(&self, dev: usize, ld: u16) -> u16 {
        self.devices[dev].mailbox.state.ld_owner[ld as usize]
    }

    /// FM side of the hot-plug doorbell: post an Event-Log record on
    /// device `dev` for the addressed host's driver to drain.
    pub fn post_fm_event(&mut self, dev: usize, rec: EventRecord) {
        self.devices[dev].mailbox.push_event(rec);
    }

    /// Fabric-wide stats: devices (with per-LD host attribution),
    /// switches and per-device leaf links.
    pub fn dump(&self, d: &mut StatDump) {
        for (j, sw) in self.switches.iter().enumerate() {
            sw.dump(&format!("cxl.sw{j}"), d);
        }
        for (i, l) in self.links.iter().enumerate() {
            l.dump(&format!("cxl.link{i}"), d);
        }
        for (i, dev) in self.devices.iter().enumerate() {
            dev.dump(&format!("cxl.dev{i}"), d);
        }
    }
}

/// One commit lane's `&mut`-disjoint view of the fabric interior: the
/// contiguous device range starting at `lo`, exactly the leaf links and
/// switches serving it, and a shared read-only copy of the route table.
/// Methods take **global** device indices and mirror the [`Fabric`]
/// traffic API one-for-one, so the commit kernel is lane-agnostic —
/// committing a lane's entries in `(tick, host, seq)` order through a
/// lane view reproduces, state-bit for state-bit, what the serial path
/// would have done to this slice of the fabric (no other lane can touch
/// it, and stats counters live inside the owned links/devices, so they
/// fold in with no separate accumulator merge).
pub struct FabricLane<'a> {
    /// First global device index of this lane's range.
    lo: usize,
    /// Leaf links for devices `lo..lo + links.len()`.
    links: &'a mut [CxlLink],
    /// Switches whose device span lies inside this lane's range,
    /// tagged with their global switch index.
    switches: Vec<(usize, &'a mut CxlSwitch)>,
    /// Full route table (read-only — shared across lanes).
    dev_switch: &'a [Option<usize>],
    /// Devices `lo..lo + devices.len()`.
    devices: &'a mut [CxlDevice],
}

impl FabricLane<'_> {
    fn switch_mut(&mut self, s: usize) -> &mut CxlSwitch {
        self.switches
            .iter_mut()
            .find(|(j, _)| *j == s)
            .map(|(_, sw)| &mut **sw)
            .expect("device routed to a switch outside its lane")
    }

    /// Lane mirror of [`Fabric::credit_link`].
    pub fn credit_link(&mut self, dev: usize) -> &mut CxlLink {
        match self.dev_switch[dev] {
            Some(s) => &mut self.switch_mut(s).us_link,
            None => &mut self.links[dev - self.lo],
        }
    }

    /// Lane mirror of [`Fabric::send_m2s`].
    pub fn send_m2s(
        &mut self,
        at: Tick,
        pkt: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        let i = dev - self.lo;
        match self.dev_switch[dev] {
            None => self.links[i].send_m2s(at, pkt),
            Some(s) => {
                let at_dsp = self.switch_mut(s).forward_m2s(at, pkt);
                self.links[i].forward_m2s(at_dsp, pkt)
            }
        }
    }

    /// Lane mirror of [`Fabric::send_s2m`].
    pub fn send_s2m(
        &mut self,
        ready: Tick,
        resp: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        let i = dev - self.lo;
        match self.dev_switch[dev] {
            None => self.links[i].send_s2m(ready, resp),
            Some(s) => {
                let at_sw = self.links[i].send_s2m(ready, resp);
                self.switch_mut(s).forward_s2m(at_sw, resp)
            }
        }
    }

    /// Lane mirror of [`Fabric::send_birsp`].
    pub fn send_birsp(
        &mut self,
        at: Tick,
        pkt: &CxlMemPacket,
        dev: usize,
    ) -> Tick {
        let i = dev - self.lo;
        match self.dev_switch[dev] {
            None => self.links[i].forward_m2s(at, pkt),
            Some(s) => {
                let at_dsp =
                    self.switch_mut(s).forward_m2s_uncredited(at, pkt);
                self.links[i].forward_m2s(at_dsp, pkt)
            }
        }
    }

    /// Lane mirror of [`Fabric::retire`].
    pub fn retire(&mut self, dev: usize, done: Tick) {
        self.credit_link(dev).retire(done);
    }

    /// The lane-owned device model for global index `dev`.
    pub fn device_mut(&mut self, dev: usize) -> &mut CxlDevice {
        &mut self.devices[dev - self.lo]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn new_builds_links_switches_devices() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 4;
        cfg.interleave_ways = 1;
        cfg.switches = 1;
        let f = Fabric::new(&cfg);
        assert_eq!(f.links.len(), 4);
        assert_eq!(f.switches.len(), 1);
        assert_eq!(f.devices.len(), 4);
        assert_eq!(f.switches[0].devices, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bind_from_config_sets_owners() {
        let mut cfg = SimConfig::default().cxl;
        cfg.interleave_ways = 1;
        cfg.dev_overrides = vec![crate::config::CxlDevOverride {
            lds: Some(2),
            ..Default::default()
        }];
        let mut f = Fabric::new(&cfg);
        // Two LD windows round-robined over two hosts.
        f.bind_from_config(&cfg, &[vec![0], vec![1]]).unwrap();
        assert_eq!(f.devices[0].mailbox.state.ld_owner, vec![0, 1]);
        // Re-binding an owned LD must fail (exclusive ownership).
        assert!(f.bind_from_config(&cfg, &[vec![0], vec![1]]).is_err());
    }

    #[test]
    fn bind_from_config_shared_mode_tracks_sharers() {
        use crate::cxl::mailbox::SHARED;
        let mut cfg = SimConfig::default().cxl;
        cfg.interleave_ways = 1;
        cfg.dev_overrides = vec![crate::config::CxlDevOverride {
            lds: Some(2),
            shared_lds: Some(vec![0]),
            ..Default::default()
        }];
        let mut f = Fabric::new(&cfg);
        // LD0 shared by hosts 0+1, LD1 private to host 1.
        f.bind_from_config(&cfg, &[vec![0, 1], vec![1]]).unwrap();
        assert_eq!(f.ld_owner(0, 0), SHARED);
        assert_eq!(f.devices[0].mailbox.state.ld_sharers[0], 0b11);
        assert_eq!(f.devices[0].mailbox.state.sharer_count(0), 2);
        assert_eq!(f.ld_owner(0, 1), 1);
    }

    #[test]
    fn runtime_fm_rebind_and_event_doorbell() {
        use crate::cxl::mailbox::{event, EventRecord, UNBOUND};
        let mut cfg = SimConfig::default().cxl;
        cfg.interleave_ways = 1;
        cfg.dev_overrides = vec![crate::config::CxlDevOverride {
            lds: Some(2),
            ..Default::default()
        }];
        let mut f = Fabric::new(&cfg);
        f.bind_from_config(&cfg, &[vec![0], vec![0]]).unwrap();
        assert_eq!(f.ld_owner(0, 1), 0);
        // Re-bind while owned fails; unbind then bind moves ownership.
        assert_eq!(f.fm_bind(0, 1, 1), retcode::BUSY);
        assert_eq!(f.fm_unbind(0, 1), retcode::SUCCESS);
        assert_eq!(f.ld_owner(0, 1), UNBOUND);
        assert_eq!(f.fm_bind(0, 1, 1), retcode::SUCCESS);
        assert_eq!(f.ld_owner(0, 1), 1);
        // The doorbell record lands in the device's event log.
        f.post_fm_event(
            0,
            EventRecord { host: 1, ld: 1, action: event::LD_BOUND },
        );
        assert_eq!(f.devices[0].mailbox.events_pending(), 1);
    }

    #[test]
    fn lane_ranges_group_by_switch_credit_pool() {
        // 8 devices behind 2 switches: two 4-device lanes.
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 8;
        cfg.interleave_ways = 1;
        cfg.switches = 2;
        let f = Fabric::new(&cfg);
        assert_eq!(f.lane_ranges(), vec![(0, 4), (4, 8)]);
        assert_eq!(
            f.lane_of_dev(&f.lane_ranges()),
            vec![0, 0, 0, 0, 1, 1, 1, 1]
        );

        // Direct attach: every device is its own lane.
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 3;
        cfg.interleave_ways = 1;
        let f = Fabric::new(&cfg);
        assert_eq!(f.lane_ranges(), vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(f.lane_of_dev(&f.lane_ranges()), vec![0, 1, 2]);
    }

    #[test]
    fn lane_views_route_like_the_fabric() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 6;
        cfg.interleave_ways = 1;
        cfg.switches = 2; // 3 devices per switch -> 2 lanes
        let mut f = Fabric::new(&cfg);
        let ranges = f.lane_ranges();
        assert_eq!(ranges, vec![(0, 3), (3, 6)]);
        let mut lanes = f.lane_views(&ranges);
        assert_eq!(lanes.len(), 2);
        // Within a lane, switched siblings resolve to the same shared
        // credit pool — the invariant the grouping exists to protect.
        let (a, b) = lanes.split_at_mut(1);
        let c0 = a[0].credit_link(0) as *const CxlLink;
        let c1 = a[0].credit_link(1) as *const CxlLink;
        assert_eq!(c0, c1, "lane siblings share one credit pool");
        let c3 = b[0].credit_link(3) as *const CxlLink;
        assert_ne!(c0, c3, "distinct lanes own distinct credit state");
        // Global device indexing works through the second lane's view.
        let d5 = b[0].device_mut(5) as *const CxlDevice;
        drop(lanes);
        assert_eq!(d5, &f.devices[5] as *const CxlDevice);
    }

    #[test]
    fn credit_link_routes_by_hierarchy() {
        let mut cfg = SimConfig::default().cxl;
        cfg.devices = 2;
        cfg.interleave_ways = 1;
        cfg.switches = 1;
        let mut f = Fabric::new(&cfg);
        // Both devices share the switch's upstream pool.
        let c0 = f.credit_link(0) as *const CxlLink;
        let c1 = f.credit_link(1) as *const CxlLink;
        assert_eq!(c0, c1, "switched siblings share one credit pool");
    }
}
