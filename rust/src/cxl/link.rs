//! CXL link model: latency + bandwidth + credit-based flow control.
//!
//! Each direction (M2S, S2M) serializes packets into 68-byte flits
//! (CXL 2.0 over PCIe 5.0 x8 by default) over a bandwidth-limited wire
//! with a fixed propagation latency. Requests consume a credit when they
//! enter the link; the credit is returned when the corresponding
//! response retires — if the device is slower than the host, the host
//! stalls on credits exactly like real CXL.mem back-pressure.

use crate::sim::{ns_to_ticks, ser_ticks, Tick};
use crate::stats::{Counter, Histogram, StatDump};

use super::mem_proto::{Channel, CxlMemPacket};

/// What the credit pool can promise a sender at a given tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CreditAvail {
    /// A credit is free right now — send immediately.
    Now,
    /// Pool exhausted; the earliest in-flight credit retires at this
    /// tick (> now), so retry then.
    RetiresAt(Tick),
    /// Pool exhausted and no in-flight credit has a timed retirement
    /// yet (every one is an unretired placeholder). The sender must
    /// re-probe after a bounded interval ([`CxlLink::reprobe_at`]) —
    /// never park on a sentinel tick.
    Unknown,
}

#[derive(Clone, Debug, Default)]
pub struct LinkStats {
    pub m2s_req: Counter,
    pub m2s_rwd: Counter,
    pub s2m_ndr: Counter,
    pub s2m_drs: Counter,
    /// CXL 3.x back-invalidate snoops (device -> host).
    pub s2m_bisnp: Counter,
    /// CXL 3.x back-invalidate responses (host -> device).
    pub m2s_birsp: Counter,
    pub flits: Counter,
    pub wire_bytes: Counter,
    pub credit_stalls: Counter,
    pub credit_wait: Histogram,
    pub occupancy_wait: Histogram,
}

#[derive(Clone, Debug)]
pub struct CxlLink {
    lat_ticks: Tick,
    bw_gbps: f64,
    flit_bytes: u64,
    /// Outstanding-request credit pool (shared M2S budget).
    credits_total: usize,
    credits_free: usize,
    /// Tick at which each in-flight credit will be returned (sorted on
    /// use). Used to compute when a stalled sender can retry.
    returns: Vec<Tick>,
    /// Wire occupancy per direction.
    m2s_free_at: Tick,
    s2m_free_at: Tick,
    pub stats: LinkStats,
}

impl CxlLink {
    pub fn new(
        lat_ns: f64,
        bw_gbps: f64,
        flit_bytes: u64,
        credits: usize,
    ) -> Self {
        CxlLink {
            lat_ticks: ns_to_ticks(lat_ns),
            bw_gbps,
            flit_bytes: flit_bytes.max(16),
            credits_total: credits.max(1),
            credits_free: credits.max(1),
            returns: Vec::new(),
            m2s_free_at: 0,
            s2m_free_at: 0,
            stats: LinkStats::default(),
        }
    }

    /// Payload bytes one flit carries: the flit size minus its framing
    /// overhead. A CXL 2.0 68 B flit packs 64 B of slot payload behind
    /// 4 B of protocol ID + CRC; the CXL 3.x 256 B flit spends 16 B on
    /// header + CRC + FEC around 240 B of payload. Charging the full
    /// flit while dividing by this capacity is what keeps wide-flit
    /// configs from being overbilled ~4x on the wire.
    fn flit_payload(&self) -> u64 {
        let overhead = if self.flit_bytes >= 128 { 16 } else { 4 };
        self.flit_bytes.saturating_sub(overhead).max(8)
    }

    /// Wire bytes after flit framing: round payload up to whole flits
    /// (of per-flit *payload* capacity), charge whole flits of wire.
    fn framed(&self, wire_bytes: u64) -> (u64, u64) {
        let flits = wire_bytes.div_ceil(self.flit_payload()).max(1);
        (flits, flits * self.flit_bytes)
    }

    fn reclaim(&mut self, now: Tick) {
        let before = self.returns.len();
        self.returns.retain(|&t| t > now);
        self.credits_free += before - self.returns.len();
    }

    /// Credit availability at `now`: [`CreditAvail::Now`] if a credit
    /// is free, the earliest timed retirement otherwise. When every
    /// in-flight credit is still an unretired placeholder there is no
    /// timed retirement to wait on — the answer is
    /// [`CreditAvail::Unknown`], and the caller re-probes at
    /// [`CxlLink::reprobe_at`] instead of parking on a sentinel (the
    /// old `Tick::MAX` answer scheduled retries at the end of time and
    /// poisoned the `credit_wait` histogram).
    pub fn credit_available_at(&mut self, now: Tick) -> CreditAvail {
        self.reclaim(now);
        if self.credits_free > 0 {
            return CreditAvail::Now;
        }
        assert!(!self.returns.is_empty(), "zero-credit link");
        match self
            .returns
            .iter()
            .copied()
            .filter(|&t| t != Tick::MAX)
            .min()
        {
            Some(t) => CreditAvail::RetiresAt(t),
            None => CreditAvail::Unknown,
        }
    }

    /// Bounded, deterministic re-probe tick for the
    /// [`CreditAvail::Unknown`] case: one link round trip past `now`
    /// (floored at 50 ns so a zero-latency test link still advances).
    pub fn reprobe_at(&self, now: Tick) -> Tick {
        now + (2 * self.lat_ticks).max(ns_to_ticks(50.0))
    }

    /// Send an M2S packet at `now`. Consumes a credit (caller must have
    /// confirmed availability via [`CxlLink::credit_available_at`]).
    /// Returns the arrival tick at the device and registers the credit
    /// to free when [`CxlLink::retire`] is called later.
    pub fn send_m2s(&mut self, now: Tick, pkt: &CxlMemPacket) -> Tick {
        self.reclaim(now);
        assert!(self.credits_free > 0, "send_m2s without credit");
        self.credits_free -= 1;
        // Placeholder: the credit returns when the response retires; we
        // record u64::MAX and fix it up in `retire`.
        self.returns.push(Tick::MAX);
        self.forward_m2s(now, pkt)
    }

    /// Move an M2S packet across the wire without touching the credit
    /// pool — the downstream hop of a switched path, where flow control
    /// lives at the shared upstream link.
    pub fn forward_m2s(&mut self, now: Tick, pkt: &CxlMemPacket) -> Tick {
        match pkt.channel {
            Channel::M2SReq => self.stats.m2s_req.inc(),
            Channel::M2SRwD => self.stats.m2s_rwd.inc(),
            // BIRsp rides its own (uncredited) M2S channel: it answers
            // a device-initiated snoop, so it must never compete for
            // the request credits it may itself be unblocking.
            Channel::M2SBIRsp => self.stats.m2s_birsp.inc(),
            _ => panic!("forward_m2s with S2M packet"),
        }
        let (flits, bytes) = self.framed(pkt.wire_bytes);
        self.stats.flits.add(flits);
        self.stats.wire_bytes.add(bytes);
        let start = now.max(self.m2s_free_at);
        self.stats.occupancy_wait.sample(start - now);
        let ser = ser_ticks(bytes, self.bw_gbps).max(1);
        self.m2s_free_at = start + ser;
        start + ser + self.lat_ticks
    }

    /// Send the S2M response at `now`; returns arrival tick at the RC.
    pub fn send_s2m(&mut self, now: Tick, pkt: &CxlMemPacket) -> Tick {
        match pkt.channel {
            Channel::S2MNdr => self.stats.s2m_ndr.inc(),
            Channel::S2MDrs => self.stats.s2m_drs.inc(),
            // Device-initiated BISnp: uncredited by construction (S2M
            // never consumed M2S request credits).
            Channel::S2MBISnp => self.stats.s2m_bisnp.inc(),
            _ => panic!("send_s2m with M2S packet"),
        }
        let (flits, bytes) = self.framed(pkt.wire_bytes);
        self.stats.flits.add(flits);
        self.stats.wire_bytes.add(bytes);
        let start = now.max(self.s2m_free_at);
        self.stats.occupancy_wait.sample(start - now);
        let ser = ser_ticks(bytes, self.bw_gbps).max(1);
        self.s2m_free_at = start + ser;
        start + ser + self.lat_ticks
    }

    /// The response for an earlier M2S packet retired at `at`: return
    /// its credit then.
    pub fn retire(&mut self, at: Tick) {
        // Fix up the earliest placeholder.
        if let Some(slot) =
            self.returns.iter_mut().find(|t| **t == Tick::MAX)
        {
            *slot = at;
        }
    }

    pub fn note_credit_stall(&mut self, now: Tick, until: Tick) {
        self.stats.credit_stalls.inc();
        self.stats.credit_wait.sample(until.saturating_sub(now));
    }

    pub fn credits_in_use(&self) -> usize {
        self.credits_total - self.credits_free
    }

    /// Credit-pool snapshot for the runtime invariant checker (rule
    /// CR-1/CR-2): `(total, free, in_flight, placeholders)`, where
    /// `in_flight` counts timed retirements still pending and
    /// `placeholders` counts `Tick::MAX` entries awaiting their
    /// [`CxlLink::retire`] fix-up. Conservation demands
    /// `free + in_flight + placeholders == total` at every tick, and
    /// `placeholders == 0` at quiesce.
    pub fn credit_audit(&self) -> (usize, usize, usize, usize) {
        let placeholders =
            self.returns.iter().filter(|&&t| t == Tick::MAX).count();
        (
            self.credits_total,
            self.credits_free,
            self.returns.len() - placeholders,
            placeholders,
        )
    }

    /// Fault hook for the checker's mutation tests: grow the issued
    /// pool without a matching free/in-flight entry, i.e. one credit
    /// has vanished from tracking. Breaks CR-1 by construction; only
    /// compiled under the `check` feature.
    #[cfg(feature = "check")]
    pub fn debug_leak_credit(&mut self) {
        self.credits_total += 1;
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.m2s_req"), &self.stats.m2s_req);
        d.counter(&format!("{path}.m2s_rwd"), &self.stats.m2s_rwd);
        d.counter(&format!("{path}.s2m_ndr"), &self.stats.s2m_ndr);
        d.counter(&format!("{path}.s2m_drs"), &self.stats.s2m_drs);
        d.counter(&format!("{path}.s2m_bisnp"), &self.stats.s2m_bisnp);
        d.counter(&format!("{path}.m2s_birsp"), &self.stats.m2s_birsp);
        d.counter(&format!("{path}.flits"), &self.stats.flits);
        d.counter(&format!("{path}.wire_bytes"), &self.stats.wire_bytes);
        d.counter(&format!("{path}.credit_stalls"), &self.stats.credit_stalls);
        d.hist(&format!("{path}.credit_wait"), &self.stats.credit_wait);
        d.hist(
            &format!("{path}.occupancy_wait"),
            &self.stats.occupancy_wait,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cxl::mem_proto::{self, HEADER_BYTES};
    use crate::sim::{MemCmd, Packet};

    fn link() -> CxlLink {
        CxlLink::new(20.0, 32.0, 68, 2)
    }

    fn read_pkt(id: u64) -> CxlMemPacket {
        mem_proto::packetize(
            &Packet::new(id, MemCmd::ReadReq, 0x1000, 64, 0, 0),
            id as u16,
        )
        .unwrap()
    }

    #[test]
    fn m2s_arrival_includes_latency_and_ser() {
        let mut l = link();
        let arr = l.send_m2s(0, &read_pkt(1));
        // 1 header flit: 68 B at 32 GB/s = 2.125 ns = 2125 ticks + 20 ns.
        assert_eq!(arr, 2125 + 20_000);
        assert_eq!(l.stats.flits.get(), 1);
    }

    #[test]
    fn rwd_uses_more_flits_than_req() {
        let mut l = link();
        let w = mem_proto::packetize(
            &Packet::new(1, MemCmd::WriteReq, 0, 64, 0, 0),
            1,
        )
        .unwrap();
        l.send_m2s(0, &read_pkt(2));
        let f1 = l.stats.flits.get();
        let mut l2 = link();
        l2.send_m2s(0, &w);
        assert!(l2.stats.flits.get() > f1);
        let _ = HEADER_BYTES;
    }

    #[test]
    fn credits_exhaust_and_return() {
        let mut l = link();
        assert_eq!(l.credit_available_at(0), CreditAvail::Now);
        l.send_m2s(0, &read_pkt(1));
        l.send_m2s(0, &read_pkt(2));
        assert_eq!(l.credits_in_use(), 2);
        // Pool (2) is exhausted; nothing retired yet -> no timed
        // retirement exists, so the answer is Unknown (bounded
        // re-probe), NOT a Tick::MAX sentinel.
        assert_eq!(l.credit_available_at(100), CreditAvail::Unknown);
        l.retire(50_000);
        assert_eq!(
            l.credit_available_at(100),
            CreditAvail::RetiresAt(50_000)
        );
        // After that tick passes, a credit is free.
        assert_eq!(l.credit_available_at(60_000), CreditAvail::Now);
        assert_eq!(l.credits_in_use(), 1);
    }

    #[test]
    fn unknown_credit_reprobe_is_bounded() {
        let mut l = link();
        l.send_m2s(0, &read_pkt(1));
        l.send_m2s(0, &read_pkt(2));
        assert_eq!(l.credit_available_at(1_000), CreditAvail::Unknown);
        // The re-probe tick is a small deterministic offset, nowhere
        // near the end of time.
        let t = l.reprobe_at(1_000);
        assert!(t > 1_000);
        assert!(t <= 1_000 + ns_to_ticks(100.0), "re-probe {t}");
        // One retirement turns Unknown into a timed answer; the other
        // placeholder must not leak back in as a sentinel.
        l.retire(9_000);
        assert_eq!(
            l.credit_available_at(1_000),
            CreditAvail::RetiresAt(9_000)
        );
    }

    #[test]
    fn forward_does_not_consume_credits() {
        let mut l = link();
        let arr = l.forward_m2s(0, &read_pkt(1));
        assert_eq!(arr, 2125 + 20_000, "same wire timing as send_m2s");
        assert_eq!(l.credits_in_use(), 0, "forwarding is uncredited");
        assert_eq!(l.stats.m2s_req.get(), 1);
    }

    #[test]
    fn wire_occupancy_serializes_back_to_back() {
        let mut l = CxlLink::new(0.0, 32.0, 68, 8);
        let a = l.send_m2s(0, &read_pkt(1));
        let b = l.send_m2s(0, &read_pkt(2));
        assert_eq!(b - a, 2125); // serialized behind the first flit
    }

    #[test]
    fn contended_wire_samples_and_dumps_occupancy_wait() {
        let mut l = CxlLink::new(0.0, 32.0, 68, 8);
        l.send_m2s(0, &read_pkt(1));
        l.send_m2s(0, &read_pkt(2)); // waits out the first flit's ser
        assert_eq!(l.stats.occupancy_wait.count(), 2);
        assert_eq!(l.stats.occupancy_wait.stats.max, 2125.0);
        // The histogram the hot path samples must actually reach the
        // stat dump (it used to be sampled but never emitted).
        let mut d = StatDump::default();
        l.dump("cxl.link0", &mut d);
        assert_eq!(d.get("cxl.link0.occupancy_wait.count"), Some(2.0));
        assert!(d.get("cxl.link0.occupancy_wait.mean").unwrap() > 0.0);
    }

    #[test]
    fn wide_flits_charge_payload_capacity_not_64b_chunks() {
        // 128 B DRS on 68 B flits: 2 x 64 B payload -> 136 wire bytes.
        let mut narrow = CxlLink::new(0.0, 32.0, 68, 8);
        let resp = mem_proto::make_response(&read_pkt(1));
        narrow.send_s2m(0, &resp);
        assert_eq!(narrow.stats.flits.get(), 2);
        assert_eq!(narrow.stats.wire_bytes.get(), 136);
        // The same DRS on a CXL 3.x-style 256 B flit fits ONE flit
        // (240 B payload capacity): 256 wire bytes, not the ~512 the
        // old `min(flit, 64)` divisor charged (2 flits x 256 B).
        let mut wide = CxlLink::new(0.0, 32.0, 256, 8);
        wide.send_s2m(0, &resp);
        assert_eq!(wide.stats.flits.get(), 1);
        assert_eq!(wide.stats.wire_bytes.get(), 256);
        // Sweeping the same traffic: wide flits may pad (256 vs 136)
        // but never by the 4x framing inflation the bug produced.
        assert!(
            wide.stats.wire_bytes.get()
                < 2 * narrow.stats.wire_bytes.get(),
            "256 B flit framing must not multiply wire bytes"
        );
    }

    #[test]
    fn s2m_independent_of_m2s_wire() {
        let mut l = link();
        let m = l.send_m2s(0, &read_pkt(1));
        let resp = mem_proto::make_response(&read_pkt(1));
        let s = l.send_s2m(0, &resp);
        // DRS = header+data = 128 B -> 2 flits = 136 B -> 4.25 ns.
        assert_eq!(s, 4250 + 20_000);
        assert!(m > 0);
    }

    #[test]
    fn bi_channels_are_uncredited_and_counted() {
        let mut l = link();
        l.send_s2m(0, &mem_proto::make_bi_snoop(0x1000, 1, 1));
        l.forward_m2s(0, &mem_proto::make_bi_response(0x1000, 1, 1, true));
        // Neither BI direction touches the M2S request credit pool —
        // that independence is what makes the flow deadlock-free.
        assert_eq!(l.credits_in_use(), 0);
        assert_eq!(l.stats.s2m_bisnp.get(), 1);
        assert_eq!(l.stats.m2s_birsp.get(), 1);
        assert_eq!(l.stats.m2s_req.get(), 0);
    }

    #[test]
    fn channel_counters() {
        let mut l = link();
        let r = read_pkt(1);
        l.send_m2s(0, &r);
        l.send_s2m(0, &mem_proto::make_response(&r));
        assert_eq!(l.stats.m2s_req.get(), 1);
        assert_eq!(l.stats.s2m_drs.get(), 1);
        assert_eq!(l.stats.s2m_ndr.get(), 0);
    }
}
