//! The CXL Type-3 memory expander endpoint.
//!
//! Owns the register blocks (component + device, BAR-mapped), the
//! mailbox engine and the media (expander DRAM) timing model. The
//! de-packetizer lives here: M2S packets arriving over the link become
//! media operations; completions go back as S2M NDR/DRS.
//!
//! A device with `lds > 1` is a **multi-logical-device** (MLD): its
//! capacity splits into `lds` equal slices, each with its own HDM
//! decoder (DPA-skip based) and per-LD traffic counters, while the
//! link, mailbox and media remain shared — the pooling granularity of
//! CXL 2.0.

use crate::config::CxlConfig;
use crate::mem::DramTiming;
use crate::sim::{ns_to_ticks, Tick};
use crate::stats::{Counter, Histogram, StatDump};

use super::mailbox::{Mailbox, MemdevState};
use super::mem_proto::{self, CxlMemPacket};
use super::regs::{dev, ComponentRegs};

#[derive(Clone, Debug, Default)]
pub struct DeviceStats {
    pub m2s_received: Counter,
    pub reads: Counter,
    pub writes: Counter,
    pub media_latency: Histogram,
    pub depacketize_ticks: Counter,
    /// Per-logical-device traffic (len = lds; index by DPA slice).
    pub ld_reads: Vec<Counter>,
    pub ld_writes: Vec<Counter>,
    /// Per-LD traffic attributed to the issuing host
    /// (`[ld][host]`, host < [`crate::config::MAX_HOSTS`]) — makes
    /// cross-host contention on a pooled MLD's media measurable.
    pub ld_host_reads: Vec<Vec<Counter>>,
    pub ld_host_writes: Vec<Vec<Counter>>,
    /// Successful runtime FM re-binds per logical device (boot-time
    /// config binding is not counted).
    pub ld_rebinds: Vec<Counter>,
}

pub struct CxlDevice {
    /// Component registers (HDM decoders, one per LD) — BAR0.
    pub component: ComponentRegs,
    /// Device registers + mailbox — BAR2.
    pub mailbox: Mailbox,
    /// Expander media.
    pub media: DramTiming,
    depkt_ticks: Tick,
    /// Device-side S2M packetization cost (responses are packed here and
    /// unpacked at the RC — symmetric with the M2S direction, Fig. 4).
    pkt_ticks: Tick,
    /// Logical devices exposed (1 = SLD).
    pub lds: usize,
    /// Capacity of one LD slice (= capacity / lds).
    ld_slice: u64,
    pub stats: DeviceStats,
    /// Where BARs were assigned (filled by BIOS/guest enumeration).
    pub bar0_base: Option<u64>,
    pub bar2_base: Option<u64>,
}

impl CxlDevice {
    /// Device 0 with the shared `[cxl]` parameters (single-card setups).
    pub fn new(cfg: &CxlConfig, serial: u64) -> Self {
        Self::new_at(cfg, 0, serial)
    }

    /// Expander card `idx`, with its per-device capacity / link /
    /// latency-class / LD-count overrides resolved.
    pub fn new_at(cfg: &CxlConfig, idx: usize, serial: u64) -> Self {
        let dev = cfg.device(idx);
        let lds = dev.lds.max(1);
        CxlDevice {
            component: ComponentRegs::new(lds),
            mailbox: Mailbox::new(MemdevState::new_mld(
                dev.mem_size,
                serial,
                lds as u16,
            )),
            media: DramTiming::new(&dev.media),
            depkt_ticks: ns_to_ticks(cfg.depkt_lat_ns),
            pkt_ticks: ns_to_ticks(cfg.pkt_lat_ns),
            lds,
            ld_slice: dev.mem_size / lds as u64,
            stats: DeviceStats {
                ld_reads: vec![Counter::default(); lds],
                ld_writes: vec![Counter::default(); lds],
                ld_host_reads: vec![
                    vec![Counter::default(); crate::config::MAX_HOSTS];
                    lds
                ],
                ld_host_writes: vec![
                    vec![Counter::default(); crate::config::MAX_HOSTS];
                    lds
                ],
                ld_rebinds: vec![Counter::default(); lds],
                ..Default::default()
            },
            bar0_base: None,
            bar2_base: None,
        }
    }

    /// Handle an M2S packet arriving at `at` from host `host`; returns
    /// (response packet, tick at which it is ready to enter the S2M
    /// channel). Single-host setups pass host 0.
    ///
    /// `hpa_to_dpa` translation: the committed HDM decoder maps a host
    /// physical range onto device physical addresses starting at 0.
    pub fn handle_m2s(
        &mut self,
        at: Tick,
        pkt: &CxlMemPacket,
        host: u8,
    ) -> (CxlMemPacket, Tick) {
        self.stats.m2s_received.inc();
        let (is_write, hpa) = mem_proto::depacketize(pkt);
        let after_depkt = at + self.depkt_ticks;
        self.stats.depacketize_ticks.add(self.depkt_ticks);

        let dpa = self.hpa_to_dpa(hpa);
        let done =
            self.media.access(after_depkt, dpa, mem_proto::DATA_BYTES, is_write);
        self.stats.media_latency.sample(done - after_depkt);
        // The DPA slice identifies the logical device served.
        let ld = ((dpa / self.ld_slice) as usize).min(self.lds - 1);
        let h = (host as usize).min(crate::config::MAX_HOSTS - 1);
        if is_write {
            self.stats.writes.inc();
            self.stats.ld_writes[ld].inc();
            self.stats.ld_host_writes[ld][h].inc();
        } else {
            self.stats.reads.inc();
            self.stats.ld_reads[ld].inc();
            self.stats.ld_host_reads[ld][h].inc();
        }
        // Pack the S2M response before it can enter the link.
        (mem_proto::make_response(pkt), done + self.pkt_ticks)
    }

    /// Translate host physical -> device physical via the committed
    /// decoder, honouring the decoder's interleave fields: for an N-way
    /// window the device sees every N-th granule, so the target-select
    /// bits are stripped — DPA = (off / (G*N)) * G + off % G (the CXL
    /// 2.0 §8.2.4.19 decode; the device never needs its slot index).
    /// The decoder's DPA skip relocates the result into its LD slice.
    /// Addresses outside any committed range map to DPA 0 (poison in
    /// real hardware; we count them).
    pub fn hpa_to_dpa(&self, hpa: u64) -> u64 {
        if self.component.hdm_enabled() {
            for i in 0..self.component.decoder_count {
                if !self.component.decoder_committed(i) {
                    continue;
                }
                let (base, size) = self.component.decoder_range(i);
                if size == 0 || hpa < base || hpa >= base + size {
                    continue;
                }
                let off = hpa - base;
                let skip = self.component.decoder_dpa_skip(i);
                let (ways, gran) = self.component.decoder_interleave(i);
                if ways == 1 {
                    return skip + off;
                }
                return skip
                    + (off / (gran * ways as u64)) * gran
                    + off % gran;
            }
        }
        // Pre-commit traffic (BIOS probing) or bad routing.
        hpa & 0xFFFF_FFFF
    }

    /// MMIO dispatch for BAR-mapped register blocks.
    pub fn mmio_read(&self, bar: u8, off: u64) -> u64 {
        match bar {
            0 => self.component.read32(off) as u64,
            2 => self.mailbox.read64(off),
            _ => !0,
        }
    }

    pub fn mmio_write(&mut self, bar: u8, off: u64, v: u64) {
        match bar {
            0 => self.component.write32(off, v as u32),
            2 => self.mailbox.write64(off, v),
            _ => {}
        }
    }

    /// Record a successful runtime FM re-bind of logical device `ld`.
    pub fn note_rebind(&mut self, ld: usize) {
        self.stats.ld_rebinds[ld.min(self.lds - 1)].inc();
    }

    pub fn capacity(&self) -> u64 {
        self.mailbox.state.total_capacity
    }

    pub fn media_ready(&self) -> bool {
        self.mailbox.read64(dev::MEMDEV_STATUS) & dev::MEDIA_READY != 0
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.m2s_received"), &self.stats.m2s_received);
        d.counter(&format!("{path}.reads"), &self.stats.reads);
        d.counter(&format!("{path}.writes"), &self.stats.writes);
        d.hist(&format!("{path}.media_latency"), &self.stats.media_latency);
        if self.lds > 1 {
            for k in 0..self.lds {
                d.counter(
                    &format!("{path}.ld{k}.reads"),
                    &self.stats.ld_reads[k],
                );
                d.counter(
                    &format!("{path}.ld{k}.writes"),
                    &self.stats.ld_writes[k],
                );
            }
        }
        for (k, r) in self.stats.ld_rebinds.iter().enumerate() {
            d.counter(&format!("{path}.ld{k}.rebinds"), r);
        }
        // Host attribution: which host's traffic each LD served (rows
        // appear once a host has actually touched the LD).
        for k in 0..self.lds {
            for h in 0..crate::config::MAX_HOSTS {
                let (r, w) = (
                    &self.stats.ld_host_reads[k][h],
                    &self.stats.ld_host_writes[k][h],
                );
                if r.get() > 0 {
                    d.counter(&format!("{path}.ld{k}.host{h}_reads"), r);
                }
                if w.get() > 0 {
                    d.counter(&format!("{path}.ld{k}.host{h}_writes"), w);
                }
            }
        }
        self.media.dump(&format!("{path}.media"), d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::{MemCmd, Packet};

    fn device() -> CxlDevice {
        let cfg = SimConfig::default().cxl;
        let mut d = CxlDevice::new(&cfg, 1);
        // Commit an HDM decoder mapping HPA [2GiB, 6GiB) -> DPA [0,4GiB).
        d.component.program_decoder(0, 2 << 30, 4 << 30);
        d.component
            .write32(super::super::regs::comp::HDM_GLOBAL_CTRL, 0b10);
        d
    }

    fn m2s(cmd: MemCmd, addr: u64) -> CxlMemPacket {
        mem_proto::packetize(&Packet::new(1, cmd, addr, 64, 0, 0), 1).unwrap()
    }

    #[test]
    fn read_returns_drs_after_depkt_plus_media() {
        let mut d = device();
        let (resp, done) = d.handle_m2s(1000, &m2s(MemCmd::ReadReq, 2 << 30), 0);
        assert_eq!(resp.channel, mem_proto::Channel::S2MDrs);
        // depkt = 25 ns; media >= tRCD+tCAS = 32 ns.
        assert!(done >= 1000 + ns_to_ticks(25.0 + 32.0));
        assert_eq!(d.stats.reads.get(), 1);
    }

    #[test]
    fn write_returns_ndr() {
        let mut d = device();
        let (resp, _) = d.handle_m2s(0, &m2s(MemCmd::WriteReq, 2 << 30), 0);
        assert_eq!(resp.channel, mem_proto::Channel::S2MNdr);
        assert_eq!(d.stats.writes.get(), 1);
    }

    #[test]
    fn hpa_translation_uses_decoder() {
        let d = device();
        assert_eq!(d.hpa_to_dpa(2 << 30), 0);
        assert_eq!(d.hpa_to_dpa((2 << 30) + 4096), 4096);
    }

    #[test]
    fn interleaved_decoder_strips_target_bits() {
        let cfg = SimConfig::default().cxl;
        let mut d = CxlDevice::new(&cfg, 1);
        // 2-way @ 256 B over an 8 GiB window: this device holds every
        // other 256 B granule, packed densely in DPA space.
        d.component.program_decoder_interleaved(0, 4 << 30, 8 << 30, 0, 1);
        d.component
            .write32(super::super::regs::comp::HDM_GLOBAL_CTRL, 0b10);
        let base = 4u64 << 30;
        assert_eq!(d.hpa_to_dpa(base), 0);
        assert_eq!(d.hpa_to_dpa(base + 100), 100);
        // Skipping the peer's granule: HPA +512 lands at DPA +256.
        assert_eq!(d.hpa_to_dpa(base + 512), 256);
        assert_eq!(d.hpa_to_dpa(base + 512 + 60), 316);
    }

    #[test]
    fn mld_slices_translate_and_count_per_ld() {
        let mut cfg = SimConfig::default().cxl;
        cfg.dev_overrides = vec![crate::config::CxlDevOverride {
            lds: Some(2),
            ..Default::default()
        }];
        let mut d = CxlDevice::new(&cfg, 1);
        assert_eq!(d.lds, 2);
        assert_eq!(d.mailbox.state.lds, 2);
        // Two LD windows: [4 GiB, 6 GiB) -> DPA [0, 2 GiB) and
        // [6 GiB, 8 GiB) -> DPA [2 GiB, 4 GiB) via decoder DPA skip.
        d.component.program_decoder_at(0, 4 << 30, 2 << 30, 0);
        d.component.program_decoder_at(1, 6 << 30, 2 << 30, 2 << 30);
        d.component
            .write32(super::super::regs::comp::HDM_GLOBAL_CTRL, 0b10);
        assert_eq!(d.hpa_to_dpa(4 << 30), 0);
        assert_eq!(d.hpa_to_dpa(6 << 30), 2 << 30);
        assert_eq!(d.hpa_to_dpa((6u64 << 30) + 4096), (2u64 << 30) + 4096);
        // Traffic lands in the right LD counter.
        d.handle_m2s(0, &m2s(MemCmd::ReadReq, 4 << 30), 0);
        d.handle_m2s(0, &m2s(MemCmd::ReadReq, 6 << 30), 1);
        d.handle_m2s(0, &m2s(MemCmd::WriteReq, 6 << 30), 1);
        assert_eq!(d.stats.ld_reads[0].get(), 1);
        assert_eq!(d.stats.ld_reads[1].get(), 1);
        assert_eq!(d.stats.ld_writes[1].get(), 1);
        assert_eq!(d.stats.reads.get(), 2);
        // Host attribution: host 0 read LD 0; host 1 owns LD 1 traffic.
        assert_eq!(d.stats.ld_host_reads[0][0].get(), 1);
        assert_eq!(d.stats.ld_host_reads[1][1].get(), 1);
        assert_eq!(d.stats.ld_host_writes[1][1].get(), 1);
        assert_eq!(d.stats.ld_host_reads[1][0].get(), 0);
        let mut dump = crate::stats::StatDump::default();
        d.dump("cxl.dev0", &mut dump);
        assert_eq!(dump.get("cxl.dev0.ld1.host1_reads"), Some(1.0));
        assert_eq!(dump.get("cxl.dev0.ld0.host0_reads"), Some(1.0));
        assert!(dump.get("cxl.dev0.ld0.host1_reads").is_none());
    }

    #[test]
    fn mmio_routes_to_blocks() {
        let mut d = device();
        // BAR0 -> component regs.
        let hdr = d.mmio_read(0, super::super::regs::comp::CAP_HDR);
        assert_eq!(hdr & 0xFFFF, 0x0001);
        // BAR2 -> mailbox.
        assert_eq!(d.mmio_read(2, dev::MB_CAPS), 9);
        d.mmio_write(2, dev::MB_CMD, 0x4200);
        d.mmio_write(2, dev::MB_CTRL, 1);
        assert_eq!(d.mailbox.status_code(), 0);
    }

    #[test]
    fn media_ready_after_construction() {
        assert!(device().media_ready());
    }

    #[test]
    fn row_locality_visible_through_device() {
        let mut d = device();
        let (_, t1) = d.handle_m2s(0, &m2s(MemCmd::ReadReq, 2 << 30), 0);
        let (_, t2) = d.handle_m2s(t1, &m2s(MemCmd::ReadReq, (2 << 30) + 64), 0);
        // Second access is a row hit: strictly faster.
        assert!(t2 - t1 < t1);
    }
}
