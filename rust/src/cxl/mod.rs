//! CXL protocol + device models (the paper's §III-B).
//!
//! * [`regs`] — the three register sets of Fig. 3: DVSEC payloads
//!   (Set 1: RC — GPF / Flexbus / Port / Register Locator), host-bridge
//!   component registers incl. HDM decoders (Set 2), and the device
//!   block with Mailbox + Status (Set 3).
//! * [`mailbox`] — the doorbell-driven mailbox command engine the
//!   CXL-CLI/ndctl emulations drive from "user space".
//! * [`mem_proto`] — the CXL.mem transaction layer of Fig. 4: M2S
//!   Req / RwD and S2M NDR / DRS with opcode-bearing headers,
//!   packetization at the root complex, de-packetization at the device.
//! * [`link`] — credit-based flit link with latency + bandwidth.
//! * [`switch`] — virtual CXL switch: shared upstream link + per-hop
//!   forwarding latency between a root port and its fanned-out
//!   endpoints.
//! * [`device`] — the Type-3 endpoint: register surface + media, with
//!   multi-logical-device (MLD) capacity slicing.
//! * [`fabric`] — the shared tree below the hosts: devices, switches
//!   and leaf links, plus the fabric-manager LD-ownership role.
//! * [`fm_policy`] — the telemetry-driven Fabric-Manager policy engine
//!   (`[fm] policy`): samples per-host/per-LD load each epoch and
//!   computes UNBIND/BIND moves with hysteresis, replacing hand-written
//!   `[fm] events` schedules with closed-loop elastic pooling.
//! * [`root_complex`] — host side (one per simulated host): HDM routing
//!   windows + packetizer, driving traffic into the fabric.

pub mod regs;
pub mod mailbox;
pub mod mem_proto;
pub mod link;
pub mod switch;
pub mod device;
pub mod fabric;
pub mod fm_policy;
pub mod root_complex;

pub use device::CxlDevice;
pub use fabric::{Fabric, FabricLane};
pub use fm_policy::FmPolicyEngine;
pub use link::{CreditAvail, CxlLink};
pub use mem_proto::{M2SOpcode, S2MOpcode};
pub use root_complex::{CxlRootComplex, HdmWindow};
pub use switch::CxlSwitch;
