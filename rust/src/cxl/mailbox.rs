//! CXL mailbox + doorbell command engine (Set 3 of Fig. 3).
//!
//! The host writes opcode + payload into the BAR-mapped mailbox
//! registers and rings the doorbell (MB_CTRL bit 0); the device executes
//! the command, clears the doorbell and posts a return code in
//! MB_STATUS. The paper highlights this as the mechanism that lets the
//! unmodified CXL-CLI/ndctl user-space toolchain talk to the modeled
//! device ("Doorbell mechanism", §III-B.1) — our `guestos::cxlcli`
//! drives exactly this surface.
//!
//! Besides the memory-device command set (IDENTIFY, partitions, health)
//! the mailbox answers the **FM-API pooling commands** (`BIND_LD` /
//! `UNBIND_LD` / `GET_LD_ALLOCATIONS` / `GET_LD_INFO`) and carries the
//! **Event Log** ([`EventRecord`]): when the fabric manager re-binds a
//! logical device at runtime it posts a record here, the status
//! register raises [`dev::EVENT_PENDING`], and the owning (or gaining)
//! guest drains it with `GET_EVENT_RECORDS` / `CLEAR_EVENT_RECORDS` —
//! the hook the memory hot-add / hot-remove path hangs off.

use super::regs::dev;

/// Memory-device command opcodes (CXL 2.0 §8.2.9.5; the 0x52xx/0x54xx
/// range carries the FM-API commands MLD-capable devices answer:
/// Get LD Info §7.6.7.1, Get LD Allocations, and the vPPB bind pair the
/// fabric manager uses to parcel LDs out to hosts — collapsed here to
/// per-LD ownership on the device, the first-order pooling semantic).
pub mod opcode {
    /// Events §8.2.9.1: read pending records from the (single modeled)
    /// event log. Payload: log id (u8, ignored — one log).
    pub const GET_EVENT_RECORDS: u16 = 0x0100;
    /// Events §8.2.9.1.3: clear the first N records (N = u16 payload).
    pub const CLEAR_EVENT_RECORDS: u16 = 0x0101;
    pub const IDENTIFY_MEMORY_DEVICE: u16 = 0x4000;
    pub const GET_PARTITION_INFO: u16 = 0x4100;
    pub const SET_PARTITION_INFO: u16 = 0x4101;
    pub const GET_HEALTH_INFO: u16 = 0x4200;
    /// FM-API Bind vPPB: payload = LD index (u16) + host id (u16).
    pub const BIND_LD: u16 = 0x5201;
    /// FM-API Unbind vPPB: payload = LD index (u16).
    pub const UNBIND_LD: u16 = 0x5202;
    pub const GET_LD_INFO: u16 = 0x5400;
    /// FM-API Get LD Allocations: LD count (u16) + per-LD owner host
    /// id (u16 each, [`super::UNBOUND`] when unassigned).
    pub const GET_LD_ALLOCATIONS: u16 = 0x5401;
}

/// Owner value of a logical device no host has been bound to.
pub const UNBOUND: u16 = 0xFFFF;

/// Owner sentinel of a logical device bound in SHARED mode (CXL 3.x):
/// no single host owns it — the sharer set lives in the per-LD bitmap
/// ([`MemdevState::ld_sharers`], appended to `GET_LD_ALLOCATIONS`).
/// Deliberately >= any real host id, so owner-indexed policy code
/// (`owner < hosts` guards) skips shared LDs without special cases.
pub const SHARED: u16 = 0xFFFE;

/// BIND_LD mode byte (optional 5th payload byte): exclusive pooling.
pub const BIND_MODE_EXCLUSIVE: u8 = 0;
/// BIND_LD mode byte: shared mapping — the host joins the LD's sharer
/// set instead of taking exclusive ownership.
pub const BIND_MODE_SHARED: u8 = 1;

/// Event-record actions carried in the device Event Log. The fabric
/// manager posts these when it re-binds logical devices at runtime;
/// the owning (or gaining) host's driver consumes them via
/// `GET_EVENT_RECORDS` and runs the memory hot-remove / hot-add path.
pub mod event {
    /// The FM wants this LD back: offline + release it (hot-remove).
    pub const UNBIND_REQUEST: u8 = 0;
    /// This LD was just bound to the addressed host (hot-add).
    pub const LD_BOUND: u8 = 1;
    /// Informational decision-log record from a telemetry-driven FM
    /// policy (`[fm] policy`): the addressed host's LD was selected
    /// for re-binding. Posted ahead of the UNBIND_REQUEST so the
    /// decision trail is visible through `GET_EVENT_RECORDS` exactly
    /// like the actions themselves; drivers log and move on.
    pub const POLICY_DECISION: u8 = 2;
}

/// One record in the device Event Log (6 bytes on the wire:
/// host u16, ld u16, action u8, reserved u8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EventRecord {
    /// Host the record is addressed to (records for other hosts are
    /// left in the log by a well-behaved driver).
    pub host: u16,
    /// Logical-device index the event concerns.
    pub ld: u16,
    /// One of [`event::UNBIND_REQUEST`] / [`event::LD_BOUND`].
    pub action: u8,
}

/// Wire size of one serialized [`EventRecord`].
pub const EVENT_RECORD_BYTES: usize = 6;

/// Mailbox return codes (§8.2.8.4.5.1).
pub mod retcode {
    pub const SUCCESS: u16 = 0x0000;
    pub const INVALID_INPUT: u16 = 0x0002;
    pub const UNSUPPORTED: u16 = 0x0003;
    pub const BUSY: u16 = 0x0006;
}

/// Multiple of capacity used by partition registers (256 MiB units).
pub const CAP_MULTIPLE: u64 = 256 << 20;

/// Device-side state the commands operate on.
#[derive(Clone, Debug)]
pub struct MemdevState {
    pub total_capacity: u64,
    /// Volatile-only SLD: active volatile capacity (rest is unprovisioned
    /// until SET_PARTITION_INFO — gives the partition commands teeth).
    pub volatile_capacity: u64,
    pub serial: u64,
    pub fw_revision: [u8; 16],
    /// Logical devices exposed (1 = SLD; > 1 = MLD pooling).
    pub lds: u16,
    /// Per-LD owner host id ([`UNBOUND`] until the FM binds it); the
    /// state BIND_LD / UNBIND_LD mutate and GET_LD_ALLOCATIONS reports.
    /// [`SHARED`] when the LD is bound in shared mode.
    pub ld_owner: Vec<u16>,
    /// Per-LD sharer-host bitmap (bit `h` = host `h` is a sharer).
    /// Non-zero only while `ld_owner` is [`SHARED`]; `MAX_HOSTS` = 64
    /// keeps the whole set in one u64.
    pub ld_sharers: Vec<u64>,
}

impl MemdevState {
    pub fn new(total_capacity: u64, serial: u64) -> Self {
        Self::new_mld(total_capacity, serial, 1)
    }

    /// An MLD exposing `lds` equal capacity slices.
    pub fn new_mld(total_capacity: u64, serial: u64, lds: u16) -> Self {
        let mut fw = [0u8; 16];
        fw[..9].copy_from_slice(b"cxlrs-1.0");
        let lds = lds.max(1);
        MemdevState {
            total_capacity,
            volatile_capacity: total_capacity,
            serial,
            fw_revision: fw,
            lds,
            ld_owner: vec![UNBOUND; lds as usize],
            ld_sharers: vec![0; lds as usize],
        }
    }

    /// Sharer hosts of `ld` (popcount of the sharer bitmap).
    pub fn sharer_count(&self, ld: u16) -> u32 {
        self.ld_sharers
            .get(ld as usize)
            .map_or(0, |b| b.count_ones())
    }
}

/// The mailbox register file + execution engine.
#[derive(Clone, Debug)]
pub struct Mailbox {
    regs: std::collections::BTreeMap<u64, u64>,
    payload: Vec<u8>,
    pub state: MemdevState,
    pub commands_executed: u64,
    /// The device Event Log: FM-posted records pending driver
    /// consumption (surfaced via [`dev::EVENT_PENDING`] in the status
    /// register and the `GET_EVENT_RECORDS` command).
    event_log: Vec<EventRecord>,
}

impl Mailbox {
    pub fn new(state: MemdevState) -> Self {
        let mut mb = Mailbox {
            regs: Default::default(),
            payload: vec![0u8; dev::MB_PAYLOAD_BYTES],
            state,
            commands_executed: 0,
            event_log: Vec::new(),
        };
        // Payload size: log2(512) = 9.
        mb.regs.insert(dev::MB_CAPS, 9);
        // Capabilities array: id 0, 1 entry (primary mailbox).
        mb.regs.insert(dev::CAP_ARRAY, 1u64 << 32);
        mb.regs.insert(dev::MEMDEV_STATUS, dev::MEDIA_READY);
        mb
    }

    // ---- MMIO surface ---------------------------------------------------
    pub fn read64(&self, off: u64) -> u64 {
        if (dev::MB_PAYLOAD..dev::MB_PAYLOAD + dev::MB_PAYLOAD_BYTES as u64)
            .contains(&off)
        {
            let i = (off - dev::MB_PAYLOAD) as usize;
            let mut b = [0u8; 8];
            let n = (self.payload.len() - i).min(8);
            b[..n].copy_from_slice(&self.payload[i..i + n]);
            return u64::from_le_bytes(b);
        }
        let mut v = *self.regs.get(&off).unwrap_or(&0);
        if off == dev::MEMDEV_STATUS && !self.event_log.is_empty() {
            v |= dev::EVENT_PENDING;
        }
        v
    }

    /// FM side: append an event record to the device Event Log (the
    /// status register's [`dev::EVENT_PENDING`] bit follows the log).
    pub fn push_event(&mut self, rec: EventRecord) {
        self.event_log.push(rec);
    }

    /// Records currently pending in the Event Log.
    pub fn events_pending(&self) -> usize {
        self.event_log.len()
    }

    pub fn write64(&mut self, off: u64, v: u64) {
        if (dev::MB_PAYLOAD..dev::MB_PAYLOAD + dev::MB_PAYLOAD_BYTES as u64)
            .contains(&off)
        {
            let i = (off - dev::MB_PAYLOAD) as usize;
            let n = (self.payload.len() - i).min(8);
            self.payload[i..i + n].copy_from_slice(&v.to_le_bytes()[..n]);
            return;
        }
        match off {
            dev::MB_CTRL => {
                self.regs.insert(dev::MB_CTRL, v);
                if v & 1 != 0 {
                    self.execute();
                }
            }
            dev::MB_CAPS | dev::MB_STATUS | dev::CAP_ARRAY
            | dev::MEMDEV_STATUS => { /* RO */ }
            _ => {
                self.regs.insert(off, v);
            }
        }
    }

    pub fn doorbell_busy(&self) -> bool {
        self.read64(dev::MB_CTRL) & 1 != 0
    }

    pub fn status_code(&self) -> u16 {
        ((self.read64(dev::MB_STATUS) >> 32) & 0xFFFF) as u16
    }

    // ---- command execution ----------------------------------------------
    fn finish(&mut self, code: u16, resp: &[u8]) {
        self.payload[..resp.len()].copy_from_slice(resp);
        // Encode response length back into MB_CMD's length field.
        let cmd = self.read64(dev::MB_CMD) & 0xFFFF;
        self.regs
            .insert(dev::MB_CMD, cmd | ((resp.len() as u64) << 16));
        self.regs.insert(dev::MB_STATUS, (code as u64) << 32);
        // Clear the doorbell: command complete.
        self.regs.insert(dev::MB_CTRL, 0);
        self.commands_executed += 1;
    }

    fn execute(&mut self) {
        let cmd = self.read64(dev::MB_CMD);
        let op = (cmd & 0xFFFF) as u16;
        let len = ((cmd >> 16) & 0x1F_FFFF) as usize;
        if len > self.payload.len() {
            self.finish(retcode::INVALID_INPUT, &[]);
            return;
        }
        match op {
            opcode::GET_EVENT_RECORDS => {
                // Count + records, oldest first. The 512 B payload fits
                // 85 records; the log never grows near that (each FM
                // action posts one and the driver drains synchronously).
                let max = (self.payload.len() - 2) / EVENT_RECORD_BYTES;
                let n = self.event_log.len().min(max);
                let mut r = vec![0u8; 2 + n * EVENT_RECORD_BYTES];
                r[0..2].copy_from_slice(&(n as u16).to_le_bytes());
                for (k, rec) in self.event_log.iter().take(n).enumerate() {
                    let o = 2 + k * EVENT_RECORD_BYTES;
                    r[o..o + 2].copy_from_slice(&rec.host.to_le_bytes());
                    r[o + 2..o + 4].copy_from_slice(&rec.ld.to_le_bytes());
                    r[o + 4] = rec.action;
                }
                self.finish(retcode::SUCCESS, &r);
            }
            opcode::CLEAR_EVENT_RECORDS => {
                if len < 2 {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                let n = u16::from_le_bytes(
                    self.payload[0..2].try_into().unwrap(),
                ) as usize;
                if n > self.event_log.len() {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                self.event_log.drain(..n);
                self.finish(retcode::SUCCESS, &[]);
            }
            opcode::IDENTIFY_MEMORY_DEVICE => {
                // §8.2.9.5.1.1 layout (prefix): fw_revision[16],
                // total_capacity (256MiB units, u64), volatile_only u64,
                // persistent u64, partition alignment u64, serial at +63.
                let mut r = vec![0u8; 80];
                r[..16].copy_from_slice(&self.state.fw_revision);
                let caps = self.state.total_capacity / CAP_MULTIPLE;
                r[16..24].copy_from_slice(&caps.to_le_bytes());
                let vol = self.state.volatile_capacity / CAP_MULTIPLE;
                r[24..32].copy_from_slice(&vol.to_le_bytes());
                // persistent = 0 (volatile SLD)
                r[40..48]
                    .copy_from_slice(&1u64.to_le_bytes()); // align: 256MiB
                r[64..72].copy_from_slice(&self.state.serial.to_le_bytes());
                self.finish(retcode::SUCCESS, &r);
            }
            opcode::GET_PARTITION_INFO => {
                let mut r = vec![0u8; 32];
                let vol = self.state.volatile_capacity / CAP_MULTIPLE;
                r[0..8].copy_from_slice(&vol.to_le_bytes());
                // next_volatile = active (no pending change)
                r[8..16].copy_from_slice(&vol.to_le_bytes());
                self.finish(retcode::SUCCESS, &r);
            }
            opcode::SET_PARTITION_INFO => {
                if len < 8 {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                let units =
                    u64::from_le_bytes(self.payload[..8].try_into().unwrap());
                let bytes = units.saturating_mul(CAP_MULTIPLE);
                if bytes > self.state.total_capacity {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                self.state.volatile_capacity = bytes;
                self.finish(retcode::SUCCESS, &[]);
            }
            opcode::GET_HEALTH_INFO => {
                let r = vec![0u8; 16]; // all-healthy
                self.finish(retcode::SUCCESS, &r);
            }
            opcode::BIND_LD => {
                // FM-API bind: give logical device `ld` to host `host`.
                // Exclusive mode (default / mode byte 0): ownership is
                // exclusive — a bound LD must be unbound before it can
                // move (the property the pooling tests assert under
                // random bind/unbind sequences). Shared mode (optional
                // 5th payload byte = 1, CXL 3.x): the host joins the
                // LD's sharer set; the owner field holds [`SHARED`]
                // and the sharer bitmap tracks membership. The two
                // modes never mix on one LD.
                if len < 4 {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                let ld =
                    u16::from_le_bytes(self.payload[0..2].try_into().unwrap());
                let host =
                    u16::from_le_bytes(self.payload[2..4].try_into().unwrap());
                let mode = if len >= 5 { self.payload[4] } else { 0 };
                if ld >= self.state.lds
                    || host as usize >= crate::config::MAX_HOSTS
                    || mode > BIND_MODE_SHARED
                {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                let owner = &mut self.state.ld_owner[ld as usize];
                if mode == BIND_MODE_SHARED {
                    if *owner != UNBOUND && *owner != SHARED {
                        // Exclusively owned: cannot be joined.
                        self.finish(retcode::BUSY, &[]);
                        return;
                    }
                    *owner = SHARED;
                    self.state.ld_sharers[ld as usize] |= 1u64 << host;
                } else {
                    if *owner != UNBOUND {
                        // Owned — or shared, which an exclusive bind
                        // can never take over.
                        self.finish(retcode::BUSY, &[]);
                        return;
                    }
                    *owner = host;
                }
                self.finish(retcode::SUCCESS, &[]);
            }
            opcode::UNBIND_LD => {
                // Payload: LD (u16). A SHARED LD additionally takes
                // the leaving host (u16) and drops only its sharer
                // bit; when the set empties the LD returns to
                // [`UNBOUND`].
                if len < 2 {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                let ld =
                    u16::from_le_bytes(self.payload[0..2].try_into().unwrap());
                if ld >= self.state.lds
                    || self.state.ld_owner[ld as usize] == UNBOUND
                {
                    self.finish(retcode::INVALID_INPUT, &[]);
                    return;
                }
                if self.state.ld_owner[ld as usize] == SHARED {
                    if len < 4 {
                        self.finish(retcode::INVALID_INPUT, &[]);
                        return;
                    }
                    let host = u16::from_le_bytes(
                        self.payload[2..4].try_into().unwrap(),
                    );
                    let bits = &mut self.state.ld_sharers[ld as usize];
                    if host as usize >= crate::config::MAX_HOSTS
                        || *bits & (1u64 << host) == 0
                    {
                        self.finish(retcode::INVALID_INPUT, &[]);
                        return;
                    }
                    *bits &= !(1u64 << host);
                    if *bits == 0 {
                        self.state.ld_owner[ld as usize] = UNBOUND;
                    }
                } else {
                    self.state.ld_owner[ld as usize] = UNBOUND;
                }
                self.finish(retcode::SUCCESS, &[]);
            }
            opcode::GET_LD_ALLOCATIONS => {
                // LD count + the owner host of each LD, in LD order,
                // then one u64 sharer bitmap per LD. The bitmaps are
                // appended AFTER the owner array so pre-sharing
                // readers, which parse only the `2 + 2 * lds` prefix,
                // keep working unchanged.
                let lds = self.state.lds as usize;
                let mut r = vec![0u8; 2 + 2 * lds + 8 * lds];
                r[0..2].copy_from_slice(&self.state.lds.to_le_bytes());
                for (k, &o) in self.state.ld_owner.iter().enumerate() {
                    r[2 + 2 * k..4 + 2 * k]
                        .copy_from_slice(&o.to_le_bytes());
                }
                let base = 2 + 2 * lds;
                for (k, &b) in self.state.ld_sharers.iter().enumerate() {
                    r[base + 8 * k..base + 8 * (k + 1)]
                        .copy_from_slice(&b.to_le_bytes());
                }
                self.finish(retcode::SUCCESS, &r);
            }
            opcode::GET_LD_INFO => {
                // FM-API Get LD Info: total memory size (u64) + LD
                // count (u16). SLDs answer with 1 so the driver probes
                // uniformly.
                let mut r = vec![0u8; 16];
                r[0..8].copy_from_slice(
                    &self.state.total_capacity.to_le_bytes(),
                );
                r[8..10].copy_from_slice(&self.state.lds.to_le_bytes());
                self.finish(retcode::SUCCESS, &r);
            }
            _ => self.finish(retcode::UNSUPPORTED, &[]),
        }
    }

    /// Host-side convenience used by the cxl-cli emulation: run a
    /// command through the real register surface (write payload, write
    /// cmd, ring doorbell, poll, read response).
    pub fn run_command(&mut self, op: u16, payload: &[u8]) -> (u16, Vec<u8>) {
        for (i, chunk) in payload.chunks(8).enumerate() {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            self.write64(
                dev::MB_PAYLOAD + (i * 8) as u64,
                u64::from_le_bytes(b),
            );
        }
        self.write64(
            dev::MB_CMD,
            (op as u64) | ((payload.len() as u64) << 16),
        );
        self.write64(dev::MB_CTRL, 1); // doorbell
        // Poll the doorbell exactly like user space would.
        let mut spins = 0;
        while self.doorbell_busy() {
            spins += 1;
            assert!(spins < 1000, "device hung");
        }
        let code = self.status_code();
        let resp_len =
            ((self.read64(dev::MB_CMD) >> 16) & 0x1F_FFFF) as usize;
        let mut resp = vec![0u8; resp_len];
        for i in 0..resp_len.div_ceil(8) {
            let v = self.read64(dev::MB_PAYLOAD + (i * 8) as u64);
            let at = i * 8;
            let n = (resp_len - at).min(8);
            resp[at..at + n].copy_from_slice(&v.to_le_bytes()[..n]);
        }
        (code, resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mb() -> Mailbox {
        Mailbox::new(MemdevState::new(4 << 30, 0xC0FFEE))
    }

    #[test]
    fn identify_reports_capacity_and_serial() {
        let mut m = mb();
        let (code, resp) =
            m.run_command(opcode::IDENTIFY_MEMORY_DEVICE, &[]);
        assert_eq!(code, retcode::SUCCESS);
        let total =
            u64::from_le_bytes(resp[16..24].try_into().unwrap());
        assert_eq!(total * CAP_MULTIPLE, 4 << 30);
        let serial = u64::from_le_bytes(resp[64..72].try_into().unwrap());
        assert_eq!(serial, 0xC0FFEE);
        assert!(resp[..9].starts_with(b"cxlrs"));
    }

    #[test]
    fn partition_get_set_roundtrip() {
        let mut m = mb();
        let (code, resp) = m.run_command(opcode::GET_PARTITION_INFO, &[]);
        assert_eq!(code, retcode::SUCCESS);
        let vol = u64::from_le_bytes(resp[0..8].try_into().unwrap());
        assert_eq!(vol * CAP_MULTIPLE, 4 << 30);

        // Shrink to 2 GiB.
        let units = (2u64 << 30) / CAP_MULTIPLE;
        let (code, _) =
            m.run_command(opcode::SET_PARTITION_INFO, &units.to_le_bytes());
        assert_eq!(code, retcode::SUCCESS);
        let (_, resp) = m.run_command(opcode::GET_PARTITION_INFO, &[]);
        let vol = u64::from_le_bytes(resp[0..8].try_into().unwrap());
        assert_eq!(vol * CAP_MULTIPLE, 2 << 30);
    }

    #[test]
    fn set_partition_beyond_capacity_rejected() {
        let mut m = mb();
        let units = (8u64 << 30) / CAP_MULTIPLE;
        let (code, _) =
            m.run_command(opcode::SET_PARTITION_INFO, &units.to_le_bytes());
        assert_eq!(code, retcode::INVALID_INPUT);
        assert_eq!(m.state.volatile_capacity, 4 << 30);
    }

    #[test]
    fn get_ld_info_reports_ld_count() {
        let mut sld = mb();
        let (code, resp) = sld.run_command(opcode::GET_LD_INFO, &[]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(
            u64::from_le_bytes(resp[0..8].try_into().unwrap()),
            4 << 30
        );
        assert_eq!(u16::from_le_bytes(resp[8..10].try_into().unwrap()), 1);

        let mut mld =
            Mailbox::new(MemdevState::new_mld(4 << 30, 0xC0FFEE, 2));
        let (code, resp) = mld.run_command(opcode::GET_LD_INFO, &[]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(u16::from_le_bytes(resp[8..10].try_into().unwrap()), 2);
    }

    #[test]
    fn bind_unbind_ld_lifecycle() {
        let mut m =
            Mailbox::new(MemdevState::new_mld(4 << 30, 0xC0FFEE, 2));
        let (code, resp) = m.run_command(opcode::GET_LD_ALLOCATIONS, &[]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(
            u16::from_le_bytes(resp[2..4].try_into().unwrap()),
            UNBOUND
        );
        // Bind LD 1 to host 2.
        let (code, _) =
            m.run_command(opcode::BIND_LD, &[1, 0, 2, 0]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(m.state.ld_owner, vec![UNBOUND, 2]);
        // Exclusive: re-binding a bound LD fails with BUSY.
        let (code, _) =
            m.run_command(opcode::BIND_LD, &[1, 0, 0, 0]);
        assert_eq!(code, retcode::BUSY);
        // Unbind frees it for a new owner.
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[1, 0]);
        assert_eq!(code, retcode::SUCCESS);
        let (code, _) =
            m.run_command(opcode::BIND_LD, &[1, 0, 0, 0]);
        assert_eq!(code, retcode::SUCCESS);
        let (_, resp) = m.run_command(opcode::GET_LD_ALLOCATIONS, &[]);
        assert_eq!(u16::from_le_bytes(resp[2..4].try_into().unwrap()), 0);
    }

    #[test]
    fn shared_bind_lifecycle() {
        let mut m =
            Mailbox::new(MemdevState::new_mld(4 << 30, 0xC0FFEE, 2));
        // Hosts 0 and 2 join LD 0 in shared mode.
        for h in [0u8, 2] {
            let (code, _) = m.run_command(
                opcode::BIND_LD,
                &[0, 0, h, 0, BIND_MODE_SHARED],
            );
            assert_eq!(code, retcode::SUCCESS);
        }
        assert_eq!(m.state.ld_owner[0], SHARED);
        assert_eq!(m.state.ld_sharers[0], 0b101);
        assert_eq!(m.state.sharer_count(0), 2);
        // Exclusive bind cannot take over a shared LD...
        let (code, _) = m.run_command(opcode::BIND_LD, &[0, 0, 1, 0]);
        assert_eq!(code, retcode::BUSY);
        // ...and shared bind cannot join an exclusively owned one.
        let (code, _) = m.run_command(opcode::BIND_LD, &[1, 0, 1, 0]);
        assert_eq!(code, retcode::SUCCESS);
        let (code, _) = m.run_command(
            opcode::BIND_LD,
            &[1, 0, 0, 0, BIND_MODE_SHARED],
        );
        assert_eq!(code, retcode::BUSY);
        // GET_LD_ALLOCATIONS: legacy prefix + appended bitmaps.
        let (code, resp) = m.run_command(opcode::GET_LD_ALLOCATIONS, &[]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(
            u16::from_le_bytes(resp[2..4].try_into().unwrap()),
            SHARED
        );
        assert_eq!(u16::from_le_bytes(resp[4..6].try_into().unwrap()), 1);
        assert_eq!(
            u64::from_le_bytes(resp[6..14].try_into().unwrap()),
            0b101
        );
        assert_eq!(
            u64::from_le_bytes(resp[14..22].try_into().unwrap()),
            0
        );
        // Per-host shared unbind: host 2 leaves, host 0 remains.
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[0, 0, 2, 0]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(m.state.ld_owner[0], SHARED);
        assert_eq!(m.state.ld_sharers[0], 0b001);
        // A non-sharer cannot leave; short payload is rejected.
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[0, 0, 3, 0]);
        assert_eq!(code, retcode::INVALID_INPUT);
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[0, 0]);
        assert_eq!(code, retcode::INVALID_INPUT);
        // Last sharer out: the LD returns to UNBOUND.
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[0, 0, 0, 0]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(m.state.ld_owner[0], UNBOUND);
        assert_eq!(m.state.sharer_count(0), 0);
    }

    #[test]
    fn bind_ld_rejects_bad_inputs() {
        let mut m = mb(); // SLD: one LD
        // LD out of range.
        let (code, _) = m.run_command(opcode::BIND_LD, &[5, 0, 0, 0]);
        assert_eq!(code, retcode::INVALID_INPUT);
        // Host out of range.
        let (code, _) =
            m.run_command(opcode::BIND_LD, &[0, 0, 0xFF, 0xFF]);
        assert_eq!(code, retcode::INVALID_INPUT);
        // Unbinding an unbound LD.
        let (code, _) = m.run_command(opcode::UNBIND_LD, &[0, 0]);
        assert_eq!(code, retcode::INVALID_INPUT);
        // Short payloads.
        let (code, _) = m.run_command(opcode::BIND_LD, &[0]);
        assert_eq!(code, retcode::INVALID_INPUT);
    }

    #[test]
    fn event_log_roundtrip_through_registers() {
        let mut m = mb();
        // Empty log: no pending bit, zero records.
        assert_eq!(m.read64(dev::MEMDEV_STATUS) & dev::EVENT_PENDING, 0);
        let (code, resp) = m.run_command(opcode::GET_EVENT_RECORDS, &[0]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(u16::from_le_bytes(resp[0..2].try_into().unwrap()), 0);

        // FM posts two records: status bit latches, records read back
        // oldest-first with host/ld/action intact.
        m.push_event(EventRecord {
            host: 1,
            ld: 3,
            action: event::UNBIND_REQUEST,
        });
        m.push_event(EventRecord { host: 0, ld: 2, action: event::LD_BOUND });
        assert_ne!(m.read64(dev::MEMDEV_STATUS) & dev::EVENT_PENDING, 0);
        let (code, resp) = m.run_command(opcode::GET_EVENT_RECORDS, &[0]);
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(u16::from_le_bytes(resp[0..2].try_into().unwrap()), 2);
        assert_eq!(u16::from_le_bytes(resp[2..4].try_into().unwrap()), 1);
        assert_eq!(u16::from_le_bytes(resp[4..6].try_into().unwrap()), 3);
        assert_eq!(resp[6], event::UNBIND_REQUEST);
        assert_eq!(resp[12], event::LD_BOUND);

        // GET does not clear; CLEAR drains the requested count.
        assert_eq!(m.events_pending(), 2);
        let (code, _) =
            m.run_command(opcode::CLEAR_EVENT_RECORDS, &2u16.to_le_bytes());
        assert_eq!(code, retcode::SUCCESS);
        assert_eq!(m.events_pending(), 0);
        assert_eq!(m.read64(dev::MEMDEV_STATUS) & dev::EVENT_PENDING, 0);
        // Over-clearing is rejected.
        let (code, _) =
            m.run_command(opcode::CLEAR_EVENT_RECORDS, &1u16.to_le_bytes());
        assert_eq!(code, retcode::INVALID_INPUT);
    }

    #[test]
    fn unsupported_opcode() {
        let mut m = mb();
        let (code, _) = m.run_command(0x9999, &[]);
        assert_eq!(code, retcode::UNSUPPORTED);
    }

    #[test]
    fn doorbell_clears_after_execution() {
        let mut m = mb();
        m.write64(dev::MB_CMD, opcode::GET_HEALTH_INFO as u64);
        m.write64(dev::MB_CTRL, 1);
        assert!(!m.doorbell_busy());
        assert_eq!(m.status_code(), retcode::SUCCESS);
        assert_eq!(m.commands_executed, 1);
    }

    #[test]
    fn media_ready_bit_set() {
        let m = mb();
        assert!(m.read64(dev::MEMDEV_STATUS) & dev::MEDIA_READY != 0);
    }

    #[test]
    fn ro_registers_ignore_writes() {
        let mut m = mb();
        m.write64(dev::MB_CAPS, 0);
        assert_eq!(m.read64(dev::MB_CAPS), 9);
    }
}
