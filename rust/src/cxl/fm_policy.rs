//! Telemetry-driven Fabric-Manager policy engine (`[fm] policy`).
//!
//! Instead of a hand-written `[fm] events` schedule, the FM samples
//! per-host and per-LD load at a deterministic `epoch` cadence
//! (machine-level `Ev::FmEpoch` entries in the unified `(tick, seq)`
//! queue) and computes UNBIND/BIND moves itself — the ROADMAP's
//! "load-driven FM policies": auto-rebalancing schedules computed from
//! stats rather than scripts.
//!
//! Two policies ship:
//!
//! * `capacity_rebalance` — the demand signal is the guest allocator's
//!   **fallback pressure** (`sys.numa_fallback_allocs` deltas: pages
//!   that spilled off their policy node because it was offline or
//!   full). The host spilling hardest gains an *idle* logical device
//!   (zero pages resident on its zNUMA node, so the hot-remove cannot
//!   be refused) from the least-pressured owner.
//! * `bandwidth_fairness` — the demand signal is per-host **CXL
//!   traffic** (fills + write-backs per epoch). The host generating
//!   the most traffic gains an idle LD from a host generating at most
//!   half as much, spreading load across more capacity/links.
//!
//! Decisions are pure functions of sampled machine state, so
//! policy-driven runs stay bit-deterministic. Hysteresis keeps the
//! closed loop stable:
//!
//! * **min-residency** — an LD never moves again until
//!   `[fm] min_residency` after its last (boot or policy) bind;
//! * **cooldown** — both hosts of a move sit out `[fm] cooldown`;
//! * **refusal back-off** — when the owning guest declines the offline
//!   (pages in use), the LD is blocked for `[fm] refusal_backoff`,
//!   doubling per consecutive refusal (capped at 8x).
//!
//! The engine only *decides*; `system::Machine` executes each
//! [`MoveDecision`] through the same quiesce → Event-Log doorbell →
//! hot-remove/add flow the scripted path uses, posting a
//! [`super::mailbox::event::POLICY_DECISION`] record first so the
//! decision trail is drainable via `GET_EVENT_RECORDS`.

use std::collections::BTreeMap;

use crate::config::{FmPolicyConfig, FmPolicyKind, LdRef};
use crate::sim::{ns_to_ticks, Tick};
use crate::stats::{Counter, StatDump};

use super::mailbox::UNBOUND;

/// Minimum per-epoch fallback-page delta before a host counts as
/// capacity-starved. Any spill is real demand (the guest wanted a node
/// it could not use); stability against noise comes from the residency
/// and cooldown gates, not from the threshold.
const MIN_CAPACITY_DEMAND: u64 = 1;
/// Minimum per-epoch CXL line-op delta before a host counts as
/// bandwidth-hungry.
const MIN_BANDWIDTH_DEMAND: u64 = 64;
/// `bandwidth_fairness` moves only toward a host with at least this
/// ratio of the donor's traffic (keeps near-equal hosts stable).
const FAIRNESS_RATIO: u64 = 2;
/// Cap on the refusal back-off doubling (2^3 = 8x).
const MAX_BACKOFF_SHIFT: u32 = 3;

/// One host's cumulative load sample (monotonic counters; the engine
/// differentiates them per epoch).
#[derive(Clone, Copy, Debug, Default)]
pub struct HostLoad {
    /// Guest allocator pages that spilled off their policy node.
    pub fallback_allocs: u64,
    /// CXL line fills + dirty write-backs issued by this host.
    pub cxl_traffic: u64,
}

/// One logical device's state at sampling time.
#[derive(Clone, Copy, Debug)]
pub struct LdState {
    pub ld: LdRef,
    /// Owning host id, [`UNBOUND`] when unassigned.
    pub owner: u16,
    /// Pages the owning guest currently has allocated on the LD's
    /// zNUMA node (0 = idle: an offline cannot be refused).
    pub resident_pages: u64,
    /// Hosts currently bound to the LD (FM-API bind state). `> 1`
    /// means BI-coherent sharing: the LD is pinned in place — moving
    /// it would yank a mapped window out from under the other sharers.
    pub sharers: u16,
    /// Cumulative back-invalidate snoops the device sent for this LD
    /// (the engine differentiates the sum per epoch as a cross-host
    /// contention signal, dumped as `fm.policy.bi_rate_last`).
    pub bi_sent: u64,
}

/// A policy decision: move `ld` from its current owner to host `to`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoveDecision {
    pub ld: LdRef,
    pub from: usize,
    pub to: usize,
}

/// Decision/outcome counters, dumped as `fm.policy.*`.
#[derive(Clone, Debug, Default)]
pub struct FmPolicyStats {
    /// Sampling epochs executed.
    pub epochs: Counter,
    /// Moves decided (and successfully executed end to end).
    pub decisions: Counter,
    /// Move executions deferred while in-flight requests to the
    /// departing window drained (quiesce re-probes).
    pub deferrals: Counter,
    /// Moves abandoned because the owning guest refused the offline
    /// (pages in use) — triggers refusal back-off.
    pub refusals: Counter,
    /// Epochs where a profitable move existed but hysteresis
    /// (min-residency, cooldown or refusal back-off) held it back.
    pub holds: Counter,
}

/// The policy engine: per-LD/per-host hysteresis state + last-epoch
/// telemetry baselines. All state lives in `BTreeMap`s/`Vec`s and all
/// inputs are deterministic machine state, so decisions replay
/// bit-identically.
pub struct FmPolicyEngine {
    kind: FmPolicyKind,
    epoch_ticks: Tick,
    min_residency: Tick,
    cooldown: Tick,
    refusal_backoff: Tick,
    /// Cumulative demand metric per host at the previous epoch.
    prev: Vec<u64>,
    /// Tick of each LD's most recent bind (absent = bound at boot, 0).
    bound_at: BTreeMap<LdRef, Tick>,
    /// Refusal back-off: the LD may not be selected before this tick.
    blocked_until: BTreeMap<LdRef, Tick>,
    /// Consecutive refusals per LD (drives the back-off doubling).
    refusal_streak: BTreeMap<LdRef, u32>,
    /// Per-host cooldown after participating in a move.
    cooldown_until: Vec<Tick>,
    /// Fabric-wide cumulative BI snoops at the previous epoch.
    prev_bi: u64,
    /// BI snoops observed during the last epoch interval (gauge).
    last_bi_rate: u64,
    pub stats: FmPolicyStats,
}

impl FmPolicyEngine {
    pub fn new(cfg: &FmPolicyConfig, hosts: usize) -> Self {
        FmPolicyEngine {
            kind: cfg.kind,
            epoch_ticks: ns_to_ticks(cfg.epoch_ns).max(1),
            min_residency: ns_to_ticks(cfg.min_residency_ns),
            cooldown: ns_to_ticks(cfg.cooldown_ns),
            refusal_backoff: ns_to_ticks(cfg.refusal_backoff_ns),
            prev: vec![0; hosts],
            bound_at: BTreeMap::new(),
            blocked_until: BTreeMap::new(),
            refusal_streak: BTreeMap::new(),
            cooldown_until: vec![0; hosts],
            prev_bi: 0,
            last_bi_rate: 0,
            stats: FmPolicyStats::default(),
        }
    }

    /// The sampling cadence in ticks (the machine schedules the next
    /// `Ev::FmEpoch` this far ahead).
    pub fn epoch_ticks(&self) -> Tick {
        self.epoch_ticks
    }

    /// The configured policy kind.
    pub fn kind(&self) -> FmPolicyKind {
        self.kind
    }

    /// Run one sampling epoch at `now`: differentiate the hosts'
    /// cumulative load, pick at most ONE move (conservative by design —
    /// the next epoch re-samples with the move's effect included), and
    /// update the telemetry baselines.
    pub fn epoch(
        &mut self,
        now: Tick,
        hosts: &[HostLoad],
        lds: &[LdState],
    ) -> Option<MoveDecision> {
        self.stats.epochs.inc();
        // Cross-host contention signal: BI snoops per epoch across all
        // shared LDs (observability for now; policies can key on it).
        let bi_cum: u64 = lds.iter().map(|s| s.bi_sent).sum();
        self.last_bi_rate = bi_cum.saturating_sub(self.prev_bi);
        self.prev_bi = bi_cum;
        let cum: Vec<u64> = hosts
            .iter()
            .map(|h| match self.kind {
                FmPolicyKind::CapacityRebalance => h.fallback_allocs,
                FmPolicyKind::BandwidthFairness => h.cxl_traffic,
            })
            .collect();
        let demand: Vec<u64> = cum
            .iter()
            .zip(self.prev.iter())
            .map(|(&c, &p)| c.saturating_sub(p))
            .collect();
        self.prev = cum;

        let min_demand = match self.kind {
            FmPolicyKind::CapacityRebalance => MIN_CAPACITY_DEMAND,
            FmPolicyKind::BandwidthFairness => MIN_BANDWIDTH_DEMAND,
        };
        // Receiver: the hungriest host (ties break toward the lower
        // id — deterministic).
        let (to, &to_demand) = demand
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0)))?;
        if to_demand < min_demand {
            return None;
        }

        // Donor candidates: someone else's *idle* LD (nothing resident
        // on its node, so the offline cannot be refused), owned by a
        // host under strictly less pressure. Sorted so selection is
        // deterministic: least-loaded owner first, then LD identity.
        let mut cands: Vec<&LdState> = lds
            .iter()
            .filter(|s| {
                s.owner != UNBOUND
                    && s.sharers <= 1
                    && (s.owner as usize) < demand.len()
                    && s.owner as usize != to
                    && s.resident_pages == 0
                    && match self.kind {
                        FmPolicyKind::CapacityRebalance => {
                            demand[s.owner as usize] < to_demand
                        }
                        FmPolicyKind::BandwidthFairness => {
                            demand[s.owner as usize] * FAIRNESS_RATIO
                                <= to_demand
                        }
                    }
            })
            .collect();
        if cands.is_empty() {
            return None;
        }
        cands.sort_by_key(|s| {
            (demand[s.owner as usize], s.owner, s.ld.dev, s.ld.ld)
        });

        // Hysteresis gates, applied per candidate: min-residency on the
        // LD, refusal back-off on the LD, cooldown on both hosts.
        for s in &cands {
            let from = s.owner as usize;
            let resided =
                now >= self.bound_at.get(&s.ld).copied().unwrap_or(0)
                    + self.min_residency;
            let unblocked = now
                >= self.blocked_until.get(&s.ld).copied().unwrap_or(0);
            let cool = now >= self.cooldown_until[from]
                && now >= self.cooldown_until[to];
            if resided && unblocked && cool {
                return Some(MoveDecision { ld: s.ld, from, to });
            }
        }
        // A profitable move existed but hysteresis held it back.
        self.stats.holds.inc();
        None
    }

    /// A decided move completed end to end: start the LD's residency
    /// clock and both hosts' cooldowns, clear any refusal streak.
    pub fn note_moved(
        &mut self,
        ld: LdRef,
        from: usize,
        to: usize,
        now: Tick,
    ) {
        self.stats.decisions.inc();
        self.bound_at.insert(ld, now);
        self.refusal_streak.remove(&ld);
        self.blocked_until.remove(&ld);
        for h in [from, to] {
            if let Some(slot) = self.cooldown_until.get_mut(h) {
                *slot = now + self.cooldown;
            }
        }
    }

    /// The owning guest refused the offline: back off exponentially
    /// (doubling per consecutive refusal, capped at 8x) before asking
    /// for this LD again.
    pub fn note_refused(&mut self, ld: LdRef, now: Tick) {
        self.stats.refusals.inc();
        let streak = self.refusal_streak.entry(ld).or_insert(0);
        let shift = (*streak).min(MAX_BACKOFF_SHIFT);
        *streak = streak.saturating_add(1);
        self.blocked_until
            .insert(ld, now + (self.refusal_backoff << shift));
    }

    /// A move execution was deferred on the quiesce gate (in-flight
    /// requests to the departing window still draining).
    pub fn note_deferred(&mut self) {
        self.stats.deferrals.inc();
    }

    /// BI snoops observed fabric-wide during the last sampling epoch.
    pub fn last_bi_rate(&self) -> u64 {
        self.last_bi_rate
    }

    pub fn dump(&self, d: &mut StatDump) {
        d.counter("fm.policy.epochs", &self.stats.epochs);
        d.counter("fm.policy.decisions", &self.stats.decisions);
        d.counter("fm.policy.deferrals", &self.stats.deferrals);
        d.counter("fm.policy.refusals", &self.stats.refusals);
        d.counter("fm.policy.holds", &self.stats.holds);
        d.push("fm.policy.bi_rate_last", self.last_bi_rate as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(kind: FmPolicyKind) -> FmPolicyEngine {
        let mut cfg = FmPolicyConfig::new(kind);
        cfg.epoch_ns = 10_000.0; // 10 us
        cfg.min_residency_ns = 20_000.0;
        cfg.cooldown_ns = 20_000.0;
        cfg.refusal_backoff_ns = 50_000.0;
        FmPolicyEngine::new(&cfg, 2)
    }

    fn ld(dev: usize, k: u16, owner: u16, resident: u64) -> LdState {
        LdState {
            ld: LdRef { dev, ld: k },
            owner,
            resident_pages: resident,
            sharers: if owner == UNBOUND { 0 } else { 1 },
            bi_sent: 0,
        }
    }

    const US: Tick = 1_000_000; // ticks per microsecond

    #[test]
    fn capacity_moves_idle_ld_to_spilling_host() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        // Host 1 spilled 100 pages; host 0 holds an idle LD 1 and a
        // busy LD 0. Residency (20 us from boot) has passed at 30 us.
        let hosts = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
        ];
        let lds = [ld(0, 0, 0, 512), ld(0, 1, 0, 0)];
        let mv = e.epoch(30 * US, &hosts, &lds).unwrap();
        assert_eq!(
            mv,
            MoveDecision { ld: LdRef { dev: 0, ld: 1 }, from: 0, to: 1 }
        );
        // Busy LD 0 was never a candidate (resident pages > 0).
    }

    #[test]
    fn residency_holds_then_releases() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let hosts = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
        ];
        let lds = [ld(0, 1, 0, 0)];
        // 10 us < 20 us min-residency from the boot bind: held.
        assert_eq!(e.epoch(10 * US, &hosts, &lds), None);
        assert_eq!(e.stats.holds.get(), 1);
        // Past residency the same situation moves. (Cumulative demand
        // is unchanged, so this epoch's delta is 0 — bump it.)
        let hosts2 = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 200, cxl_traffic: 0 },
        ];
        assert!(e.epoch(25 * US, &hosts2, &lds).is_some());
    }

    #[test]
    fn cooldown_after_move_prevents_ping_pong() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let r = LdRef { dev: 0, ld: 1 };
        e.note_moved(r, 0, 1, 30 * US);
        assert_eq!(e.stats.decisions.get(), 1);
        // Immediately after, host 0 becomes the hungry one and the
        // moved LD sits idle on host 1 — but residency + cooldown hold.
        let hosts = [
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
            HostLoad::default(),
        ];
        let lds = [ld(0, 1, 1, 0)];
        assert_eq!(e.epoch(40 * US, &hosts, &lds), None);
        assert_eq!(e.stats.holds.get(), 1);
        // Once both expire (30 + 20 us residency and cooldown), the
        // reverse move is allowed again.
        let hosts2 = [
            HostLoad { fallback_allocs: 200, cxl_traffic: 0 },
            HostLoad::default(),
        ];
        assert!(e.epoch(55 * US, &hosts2, &lds).is_some());
    }

    #[test]
    fn refusal_backoff_doubles_and_caps() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let r = LdRef { dev: 0, ld: 0 };
        e.note_refused(r, 0);
        assert_eq!(e.blocked_until[&r], 50 * US);
        e.note_refused(r, 0);
        assert_eq!(e.blocked_until[&r], 100 * US);
        e.note_refused(r, 0);
        e.note_refused(r, 0);
        e.note_refused(r, 0);
        // Capped at 8x even as the streak keeps growing.
        assert_eq!(e.blocked_until[&r], 400 * US);
        assert_eq!(e.stats.refusals.get(), 5);
        // A successful move clears the streak and the block.
        e.note_moved(r, 0, 1, 500 * US);
        assert!(e.blocked_until.get(&r).is_none());
    }

    #[test]
    fn demand_is_differentiated_per_epoch() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let lds = [ld(0, 1, 0, 0)];
        let hosts = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
        ];
        assert!(e.epoch(30 * US, &hosts, &lds).is_some());
        // Same cumulative value next epoch -> delta 0 -> no demand.
        let lds2 = [ld(0, 0, 0, 0)];
        assert_eq!(e.epoch(40 * US, &hosts, &lds2), None);
        assert_eq!(
            e.stats.holds.get(),
            0,
            "no demand is not a hysteresis hold"
        );
    }

    #[test]
    fn bandwidth_fairness_requires_traffic_ratio() {
        let mut e = engine(FmPolicyKind::BandwidthFairness);
        // Host 1 pushes 1000 line ops, host 0 owns an idle LD and
        // pushes 600: ratio < 2, stable.
        let hosts = [
            HostLoad { fallback_allocs: 0, cxl_traffic: 600 },
            HostLoad { fallback_allocs: 0, cxl_traffic: 1000 },
        ];
        let lds = [ld(0, 0, 0, 0), ld(0, 1, 1, 128)];
        assert_eq!(e.epoch(30 * US, &hosts, &lds), None);
        // Next epoch host 1 doubles its lead: the idle LD moves.
        let hosts2 = [
            HostLoad { fallback_allocs: 0, cxl_traffic: 700 },
            HostLoad { fallback_allocs: 0, cxl_traffic: 2200 },
        ];
        let mv = e.epoch(40 * US, &hosts2, &lds).unwrap();
        assert_eq!(mv.ld, LdRef { dev: 0, ld: 0 });
        assert_eq!((mv.from, mv.to), (0, 1));
    }

    #[test]
    fn shared_lds_are_pinned_and_bi_rate_differentiates() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let hosts = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
        ];
        // Idle, would otherwise move — but two sharers pin it in place.
        let mut s = ld(0, 1, 0, 0);
        s.sharers = 2;
        s.bi_sent = 40;
        assert_eq!(e.epoch(30 * US, &hosts, &[s]), None);
        assert_eq!(e.last_bi_rate(), 40);
        // The BI signal is differentiated per epoch, not cumulative.
        let mut s2 = s;
        s2.bi_sent = 100;
        let hosts2 = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 200, cxl_traffic: 0 },
        ];
        assert_eq!(e.epoch(40 * US, &hosts2, &[s2]), None);
        assert_eq!(e.last_bi_rate(), 60);
    }

    #[test]
    fn unbound_and_foreign_lds_are_never_candidates() {
        let mut e = engine(FmPolicyKind::CapacityRebalance);
        let hosts = [
            HostLoad::default(),
            HostLoad { fallback_allocs: 100, cxl_traffic: 0 },
        ];
        // Unbound LD, the receiver's own LD, and a busy LD: no move.
        let lds = [
            ld(0, 0, UNBOUND, 0),
            ld(0, 1, 1, 0),
            ld(1, 0, 0, 64),
        ];
        assert_eq!(e.epoch(30 * US, &hosts, &lds), None);
    }
}
