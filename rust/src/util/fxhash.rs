//! Fast non-cryptographic hasher for hot-path maps (FxHash-style).
//!
//! The simulator's inner loop does several `HashMap<u64, _>` lookups per
//! memory operation (physical page store, page tables, the L2 pending
//! table, the directory). std's default SipHash is DoS-resistant but
//! ~5x slower than a multiplicative hash for integer keys; none of these
//! maps are attacker-facing. Swapping the hasher was perf-pass change #1
//! (EXPERIMENTS.md §Perf).

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, v: u64) {
        self.hash = (self.hash.rotate_left(5) ^ v).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut b = [0u8; 8];
            b[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(b));
        }
    }

    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.add(v);
    }

    #[inline]
    fn write_u32(&mut self, v: u32) {
        self.add(v as u64);
    }

    #[inline]
    fn write_usize(&mut self, v: usize) {
        self.add(v as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works_and_distributes() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..10_000u64 {
            m.insert(i * 4096, i);
        }
        assert_eq!(m.len(), 10_000);
        for i in (0..10_000u64).step_by(7) {
            assert_eq!(m.get(&(i * 4096)), Some(&i));
        }
    }

    #[test]
    fn hash_differs_for_nearby_keys() {
        let h = |v: u64| {
            let mut hh = FxHasher::default();
            hh.write_u64(v);
            hh.finish()
        };
        // Page-aligned keys (low bits zero) must still spread.
        let a = h(0x1000);
        let b = h(0x2000);
        let c = h(0x3000);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a & 0xFFFF, b & 0xFFFF, "low bits must differ");
    }

    #[test]
    fn set_dedups() {
        let mut s: FxHashSet<u64> = FxHashSet::default();
        s.insert(5);
        s.insert(5);
        assert_eq!(s.len(), 1);
    }
}
