//! Minimal JSON parser + writer (offline environment — no serde).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are passed
//! through unvalidated. Used for `artifacts/manifest.json`, stat dumps
//! and bench result files.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object builder convenience.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte '{}'", c as char))),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("eof in string"))? {
                b'"' => return Ok(out),
                b'\\' => {
                    match self.bump().ok_or_else(|| self.err("eof in escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let mut code = 0u32;
                            for _ in 0..4 {
                                let c = self
                                    .bump()
                                    .ok_or_else(|| self.err("eof in \\u"))?;
                                code = code * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let s = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn esc(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => esc(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                esc(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":false}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Bool(false)));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape_and_utf8() {
        let j = Json::parse("\"\\u00e9 caf\u{e9}\"").unwrap();
        assert_eq!(j.as_str(), Some("\u{e9} caf\u{e9}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":null,"d":true}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("4096").unwrap().as_u64(), Some(4096));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }
}
