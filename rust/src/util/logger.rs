//! Tiny `log`-facade backend: level from `CXLRAMSIM_LOG` (error..trace),
//! writes to stderr with the simulated tick when available.

use std::sync::atomic::{AtomicU64, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

static CURRENT_TICK: AtomicU64 = AtomicU64::new(0);

/// Event loops publish the current tick so log lines carry sim time.
pub fn set_tick(t: u64) {
    CURRENT_TICK.store(t, Ordering::Relaxed);
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tick = CURRENT_TICK.load(Ordering::Relaxed);
            eprintln!(
                "[{:>5} t={}] {}: {}",
                level_str(record.level()),
                tick,
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

fn level_str(l: Level) -> &'static str {
    match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN",
        Level::Info => "INFO",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    }
}

/// Install the logger once; safe to call repeatedly.
pub fn init() {
    static LOGGER: StderrLogger = StderrLogger;
    let filter = match std::env::var("CXLRAMSIM_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("info") => LevelFilter::Info,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        _ => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(filter);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        super::set_tick(123);
        log::warn!("logger self-test line");
    }
}
