//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate's vendored
//! dependency closure is available), so the pieces a project would
//! normally pull from crates.io — PRNG, JSON/TOML parsing, property
//! testing, a criterion-style bench harness, a logger — are implemented
//! here from scratch and tested like any other module.

pub mod rng;
pub mod json;
pub mod toml;
pub mod prop;
pub mod bench;
pub mod logger;
pub mod fxhash;

/// Integer ceil-div.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// `true` iff `x` is a power of two (and nonzero).
#[inline]
pub fn is_pow2(x: u64) -> bool {
    x != 0 && (x & (x - 1)) == 0
}

/// log2 of a power of two.
#[inline]
pub fn log2(x: u64) -> u32 {
    debug_assert!(is_pow2(x));
    x.trailing_zeros()
}

/// Pretty-print a byte size (`1572864` -> `"1.5 MiB"`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else if (v - v.round()).abs() < 1e-9 {
        format!("{} {}", v.round() as u64, UNITS[u])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn pow2_and_log2() {
        assert!(is_pow2(1));
        assert!(is_pow2(64));
        assert!(!is_pow2(0));
        assert!(!is_pow2(12));
        assert_eq!(log2(1), 0);
        assert_eq!(log2(4096), 12);
    }

    #[test]
    fn human_bytes_format() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1024), "1 KiB");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(1 << 20), "1 MiB");
        assert_eq!(human_bytes(3 * (1 << 30) / 2), "1.5 GiB");
    }
}
