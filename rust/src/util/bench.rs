//! Criterion-style bench harness (offline — no criterion).
//!
//! Each `benches/*.rs` is a `harness = false` binary that uses
//! [`BenchRunner`] for wall-clock measurement (warmup + N samples,
//! median/p10/p90) and [`Table`] to print the paper's tables/series in a
//! stable, diffable format. Results are also appended as JSON lines so
//! EXPERIMENTS.md numbers are regenerable.

use std::time::{Duration, Instant};

/// Timing statistics over samples.
#[derive(Clone, Copy, Debug)]
pub struct Samples {
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
    pub iters: u64,
}

impl Samples {
    pub fn per_iter(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }
}

pub struct BenchRunner {
    pub warmup: usize,
    pub samples: usize,
    pub min_sample_time: Duration,
    results: Vec<(String, Samples)>,
    suite: String,
}

impl BenchRunner {
    pub fn new(suite: &str) -> Self {
        // Honor the same quick-run env knob our CI uses.
        let quick = std::env::var("CXLRAMSIM_BENCH_QUICK").is_ok();
        BenchRunner {
            warmup: if quick { 1 } else { 3 },
            samples: if quick { 3 } else { 10 },
            min_sample_time: Duration::from_millis(if quick { 10 } else { 50 }),
            results: Vec::new(),
            suite: suite.to_string(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Samples {
        for _ in 0..self.warmup {
            f();
        }
        // Choose an iteration count so each sample runs >= min_sample_time.
        let t = Instant::now();
        f();
        let one = t.elapsed().max(Duration::from_nanos(100));
        let iters = (self.min_sample_time.as_nanos() / one.as_nanos())
            .clamp(1, 1_000_000) as u64;

        let mut per_iter: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        per_iter.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            let idx = ((per_iter.len() - 1) as f64 * p).round() as usize;
            per_iter[idx]
        };
        let s = Samples {
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
            mean_ns: per_iter.iter().sum::<f64>() / per_iter.len() as f64,
            iters,
        };
        println!(
            "{}/{}: median {:>12} (p10 {}, p90 {}) x{}",
            self.suite,
            name,
            fmt_ns(s.median_ns),
            fmt_ns(s.p10_ns),
            fmt_ns(s.p90_ns),
            iters
        );
        self.results.push((name.to_string(), s));
        s
    }

    /// Write accumulated results to `target/bench-results/<suite>.jsonl`.
    pub fn finish(&self) {
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let mut out = String::new();
        for (name, s) in &self.results {
            out.push_str(&format!(
                "{{\"suite\":\"{}\",\"bench\":\"{}\",\"median_ns\":{:.1},\
                 \"p10_ns\":{:.1},\"p90_ns\":{:.1},\"iters\":{}}}\n",
                self.suite, name, s.median_ns, s.p10_ns, s.p90_ns, s.iters
            ));
        }
        let _ = std::fs::write(dir.join(format!("{}.jsonl", self.suite)), out);
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Fixed-width table printer for paper tables/series.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.to_string(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut w: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let line: String = w
            .iter()
            .map(|n| "-".repeat(n + 2))
            .collect::<Vec<_>>()
            .join("+");
        println!("\n== {} ==", self.title);
        println!("{line}");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{line}");
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
        println!("{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("CXLRAMSIM_BENCH_QUICK", "1");
        let mut r = BenchRunner::new("selftest");
        let mut acc = 0u64;
        let s = r.bench("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.p90_ns);
        std::hint::black_box(acc);
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 us");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.000 s");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["x".into()]);
    }
}
