//! TOML-subset parser for simulator config files (offline — no serde).
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! string / integer (dec, hex `0x`, underscores, size suffixes `KiB MiB
//! GiB` and `K M G`) / float / bool / homogeneous arrays, `#` comments.
//! Unsupported (rejected, not silently ignored): arrays-of-tables,
//! multi-line strings, dotted keys on the LHS, datetimes.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_int().and_then(|i| u64::try_from(i).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: flat map of `"section.key"` -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = ln + 1;
            let err = |msg: &str| TomlError { line, msg: msg.into() };
            let s = strip_comment(raw).trim();
            if s.is_empty() {
                continue;
            }
            if let Some(rest) = s.strip_prefix('[') {
                if s.starts_with("[[") {
                    return Err(err("arrays-of-tables unsupported"));
                }
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header"))?
                    .trim();
                if name.is_empty()
                    || !name.chars().all(|c| {
                        c.is_ascii_alphanumeric() || c == '_' || c == '.'
                            || c == '-'
                    })
                {
                    return Err(err("bad section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = s.find('=').ok_or_else(|| err("expected key = value"))?;
            let key = s[..eq].trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(err("bad key"));
            }
            let val = parse_value(s[eq + 1..].trim())
                .map_err(|m| err(&m))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(full.clone(), val).is_some() {
                return Err(err(&format!("duplicate key '{full}'")));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    /// Apply a `key=value` CLI override (same value grammar).
    pub fn set_override(&mut self, kv: &str) -> Result<(), String> {
        let eq = kv.find('=').ok_or("override must be key=value")?;
        let key = kv[..eq].trim().to_string();
        let val = parse_value(kv[eq + 1..].trim())?;
        self.entries.insert(key, val);
        Ok(())
    }
}

fn strip_comment(s: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &s[..i],
            _ => {}
        }
    }
    s
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let end = body.find('"').ok_or("unterminated string")?;
        if !body[end + 1..].trim().is_empty() {
            return Err("trailing garbage after string".into());
        }
        return Ok(TomlValue::Str(body[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let mut out = Vec::new();
        let body = body.trim();
        if !body.is_empty() {
            for part in split_top(body) {
                out.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    parse_scalar(s)
}

/// Split an array body on top-level commas (no nested arrays-of-arrays
/// with strings containing commas are used in our configs, but strings
/// are respected).
fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn parse_scalar(s: &str) -> Result<TomlValue, String> {
    // Size suffixes first: "64 KiB", "1GiB", "2M".
    for (suf, mult) in [
        ("KiB", 1u64 << 10),
        ("MiB", 1u64 << 20),
        ("GiB", 1u64 << 30),
        ("TiB", 1u64 << 40),
        ("K", 1u64 << 10),
        ("M", 1u64 << 20),
        ("G", 1u64 << 30),
    ] {
        if let Some(num) = s.strip_suffix(suf) {
            let num = num.trim();
            if let Ok(v) = parse_int(num) {
                let r = (v as u64)
                    .checked_mul(mult)
                    .ok_or("size overflow")?;
                return i64::try_from(r)
                    .map(TomlValue::Int)
                    .map_err(|_| "size overflow".into());
            }
        }
    }
    if let Ok(v) = parse_int(s) {
        return Ok(TomlValue::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value '{s}'"))
}

fn parse_int(s: &str) -> Result<i64, ()> {
    let clean: String = s.chars().filter(|c| *c != '_').collect();
    if let Some(hex) = clean.strip_prefix("0x") {
        return i64::from_str_radix(hex, 16).map_err(|_| ());
    }
    clean.parse::<i64>().map_err(|_| ())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_doc() {
        let doc = TomlDoc::parse(
            r#"
# comment
title = "cxl"
[system]
cores = 4
freq_ghz = 3.0
o3 = true
[system.l2]
size = 1 MiB
assoc = 16
sizes = [1, 2, 3]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("title").unwrap().as_str(), Some("cxl"));
        assert_eq!(doc.get("system.cores").unwrap().as_int(), Some(4));
        assert_eq!(doc.get("system.freq_ghz").unwrap().as_f64(), Some(3.0));
        assert_eq!(doc.get("system.o3").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("system.l2.size").unwrap().as_int(),
            Some(1 << 20)
        );
        assert_eq!(
            doc.get("system.l2.sizes").unwrap(),
            &TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(2),
                TomlValue::Int(3)
            ])
        );
    }

    #[test]
    fn size_suffixes_and_hex() {
        let doc =
            TomlDoc::parse("a = 64KiB\nb = 0x1000\nc = 2G\nd = 1_000_000")
                .unwrap();
        assert_eq!(doc.get("a").unwrap().as_int(), Some(64 << 10));
        assert_eq!(doc.get("b").unwrap().as_int(), Some(4096));
        assert_eq!(doc.get("c").unwrap().as_int(), Some(2 << 30));
        assert_eq!(doc.get("d").unwrap().as_int(), Some(1_000_000));
    }

    #[test]
    fn rejects_bad_docs() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("x 1").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = 1\nk = 2").is_err());
        assert!(TomlDoc::parse("[[t]]").is_err());
    }

    #[test]
    fn comments_in_strings() {
        let doc = TomlDoc::parse("k = \"a#b\" # real comment").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn overrides() {
        let mut doc = TomlDoc::parse("a = 1").unwrap();
        doc.set_override("a=2").unwrap();
        doc.set_override("sys.new=\"x\"").unwrap();
        assert_eq!(doc.get("a").unwrap().as_int(), Some(2));
        assert_eq!(doc.get("sys.new").unwrap().as_str(), Some("x"));
        assert!(doc.set_override("nope").is_err());
    }
}
