//! Deterministic PRNG (SplitMix64 seeding a xoshiro256**).
//!
//! The simulator must be bit-reproducible across runs for a given seed —
//! CI compares stat dumps — so we use our own PRNG rather than anything
//! platform-dependent.

/// xoshiro256** with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into the full state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's method (bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; slight modulo bias is irrelevant for
        // simulation workloads but we debias with one rejection round.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound && lo.wrapping_neg() % bound != 0 {
                // fall through (accept) — the standard early-accept check
            }
            if lo >= bound || x >= bound.wrapping_neg() % bound.max(1) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element (panics on empty).
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Zipf(s) sampler over `0..n` via a precomputed CDF + binary search.
///
/// Serving-fleet request mixes are Zipf-distributed over the user
/// population (a few users own most of the traffic); the CDF is built
/// once so sampling is O(log n) and — like everything fed by [`Rng`] —
/// bit-deterministic for a given seed.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` items, exponent `s` (s = 0 degenerates to uniform).
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0, "zipf needs a non-empty population");
        assert!(s >= 0.0 && s.is_finite(), "zipf exponent must be >= 0");
        let mut cdf = Vec::with_capacity(n as usize);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn n(&self) -> u64 {
        self.cdf.len() as u64
    }

    /// Draw one item id in `0..n` (0 is the most popular).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.f64();
        // min() guards the float-rounding case where the final CDF
        // entry lands a hair under 1.0.
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
            as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        const N: usize = 20_000;
        for _ in 0..N {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_skews_toward_low_ids() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(17);
        let mut counts = [0u64; 100];
        const N: usize = 50_000;
        for _ in 0..N {
            counts[z.sample(&mut r) as usize] += 1;
        }
        // Item 0 dominates item 50 by roughly 50^1.1; allow slack.
        assert!(counts[0] > 20 * counts[50].max(1), "{counts:?}");
        // Every draw stayed in range (counts sums to N).
        assert_eq!(counts.iter().sum::<u64>(), N as u64);
    }

    #[test]
    fn zipf_zero_exponent_is_uniformish() {
        let z = Zipf::new(10, 0.0);
        let mut r = Rng::new(23);
        let mut counts = [0u64; 10];
        for _ in 0..20_000 {
            counts[z.sample(&mut r) as usize] += 1;
        }
        for c in counts {
            let frac = c as f64 / 20_000.0;
            assert!((frac - 0.1).abs() < 0.02, "{counts:?}");
        }
    }

    #[test]
    fn zipf_deterministic_for_seed() {
        let z = Zipf::new(1000, 1.0);
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..500 {
            assert_eq!(z.sample(&mut a), z.sample(&mut b));
        }
    }

    #[test]
    fn zipf_single_item_population() {
        let z = Zipf::new(1, 1.3);
        let mut r = Rng::new(3);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut r), 0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
