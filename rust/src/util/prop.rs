//! Mini property-based testing framework (offline — no proptest).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` inputs drawn by
//! `gen` from a deterministic PRNG, and on failure performs greedy
//! shrinking via the input's [`Shrink`] implementation before panicking
//! with the minimal counterexample. Used by the coordinator-invariant and
//! cache/coherence property tests.

use super::rng::Rng;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(0);
            v.push(self / 2);
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as u32).collect()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for bool {
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let n = self.len();
        if n == 0 {
            return out;
        }
        // Halve, drop one element, shrink one element.
        out.push(self[..n / 2].to_vec());
        if n > 1 {
            out.push(self[1..].to_vec());
            out.push(self[..n - 1].to_vec());
        }
        for i in 0..n.min(8) {
            for s in self[i].shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` generated inputs; panic with the shrunk
/// minimal counterexample on failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    // Seed from the property name so each property explores a distinct
    // but reproducible stream.
    let seed = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property '{name}' failed (case {case}/{cases}):\n  \
                 error: {min_msg}\n  minimal input: {min:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink, P: Fn(&T) -> Result<(), String>>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // Greedy descent, bounded to avoid pathological blowup.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            200,
            |r| (r.below(1000), r.below(1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn failing_property_panics_with_counterexample() {
        check(
            "always-small",
            500,
            |r| r.below(1000),
            |&x| {
                if x < 900 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn shrink_finds_small_vec() {
        // Property: no vector contains a 7. Verify shrinking reaches a
        // minimal single-element-ish example by running the loop directly.
        let prop = |v: &Vec<u64>| {
            if v.contains(&7) {
                Err("has 7".into())
            } else {
                Ok(())
            }
        };
        let bad = vec![1, 2, 7, 9, 7, 3];
        let (min, _) = shrink_loop(bad, "has 7".into(), &prop);
        assert!(min.contains(&7));
        assert!(min.len() <= 2, "shrunk to {min:?}");
    }

    #[test]
    fn u64_shrink_descends() {
        assert!(0u64.shrink().is_empty());
        assert!(10u64.shrink().contains(&0));
    }
}
