//! Trace replay workload (`[workload] kind = "replay"`).
//!
//! Re-executes a captured [`EventTrace`](crate::trace::EventTrace)
//! bit-deterministically: `setup` re-mmaps the recorded VMAs (same
//! lengths, order and policies — and asserts the deterministic mmap
//! cursor hands back the recorded VAs), `init_data` replays the
//! recorded functional init writes (reproducing attach-time page
//! placement), and `next_op` streams the recorded op sequence. Under
//! the same machine config, a replay run is event-for-event identical
//! to the live run it was captured from — the property the pinned
//! bench traces and CI regressions rely on.

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::trace::{EventTrace, TraceOp};

use super::{WlStat, Workload};

/// One (host, core)'s slice of a captured trace.
pub struct Replay {
    vmas: Vec<(u64, u64, MemPolicy)>, // (recorded start, len, policy)
    inits: Vec<(u64, u64)>,
    ops: Vec<WlOp>,
    at: usize,
    bytes: u64,
}

impl Replay {
    /// Extract the `(host, core)` stream from `t`. Cores not present
    /// in the trace yield an empty replay (immediately done).
    pub fn from_trace(t: &EventTrace, host: usize, core: usize) -> Self {
        let (h, c) = (host as u8, core as u8);
        let vmas = t
            .vmas
            .iter()
            .filter(|v| v.host == h && v.core == c)
            .map(|v| {
                let pol = MemPolicy::parse(&v.policy)
                    .expect("load-validated policy spec");
                (v.start, v.len, pol)
            })
            .collect();
        let inits = t
            .inits
            .iter()
            .filter(|i| i.host == h && i.core == c)
            .map(|i| (i.va, i.bits))
            .collect();
        let mut bytes = 0u64;
        let ops = t
            .events
            .iter()
            .filter(|e| e.host == h && e.core == c)
            .map(|e| match e.op {
                TraceOp::Load => {
                    bytes += e.size as u64;
                    WlOp::Load { va: e.arg, size: e.size as u32 }
                }
                TraceOp::Store => {
                    bytes += e.size as u64;
                    WlOp::Store { va: e.arg, size: e.size as u32 }
                }
                TraceOp::Work => WlOp::Work { cycles: e.arg },
            })
            .collect();
        Replay { vmas, inits, ops, at: 0, bytes }
    }

    /// All of `host`'s per-core replays, dense from core 0 up to the
    /// highest core the trace recorded for it (gap cores get empty
    /// replays so core indices line up). Empty when the host is absent.
    pub fn for_host(t: &EventTrace, host: usize) -> Vec<Box<dyn Workload>> {
        let Some(max_core) = t.max_core(host as u8) else {
            return Vec::new();
        };
        (0..=max_core as usize)
            .map(|c| Box::new(Replay::from_trace(t, host, c)) as Box<dyn Workload>)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

impl Workload for Replay {
    fn name(&self) -> String {
        format!("replay-{}ops", self.ops.len())
    }

    fn setup(&mut self, asp: &mut AddressSpace, _policy: &MemPolicy) {
        for &(start, len, ref pol) in &self.vmas {
            let va = asp.mmap(len, pol.clone());
            // The mmap cursor is deterministic, so under the recorded
            // config the recorded VAs must come back verbatim; anything
            // else means the trace is being replayed against a
            // different address-space history.
            assert_eq!(
                va, start,
                "replay VMA landed at {va:#x}, trace recorded {start:#x} \
                 (trace/config mismatch)"
            );
        }
    }

    fn next_op(&mut self) -> Option<WlOp> {
        let op = self.ops.get(self.at).copied()?;
        self.at += 1;
        Some(op)
    }

    fn init_data(&self) -> Vec<(u64, u64)> {
        self.inits.clone()
    }

    fn extra_stats(&self) -> Vec<(String, WlStat)> {
        vec![
            ("trace.replay_ops".into(), WlStat::Count(self.at as u64)),
            (
                "trace.replay_vmas".into(),
                WlStat::Count(self.vmas.len() as u64),
            ),
        ]
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{InitRecord, MemEvent, VmaRecord};
    use crate::workloads::testutil::{drain, world};

    fn mini_trace() -> EventTrace {
        let mut t = EventTrace::default();
        // Core 0's first mmap lands at the canonical base.
        t.vmas.push(VmaRecord {
            host: 0,
            core: 0,
            start: 0x7f00_0000_0000,
            len: 8192,
            policy: "local".into(),
        });
        t.inits.push(InitRecord {
            host: 0,
            core: 0,
            va: 0x7f00_0000_0000,
            bits: 0xdead_beef,
        });
        for i in 0..10u64 {
            t.events.push(MemEvent {
                host: 0,
                core: 0,
                op: if i % 3 == 0 { TraceOp::Store } else { TraceOp::Load },
                size: 8,
                arg: 0x7f00_0000_0000 + i * 64,
            });
        }
        // A second host the first must not see.
        t.events.push(MemEvent {
            host: 1,
            core: 0,
            op: TraceOp::Work,
            size: 0,
            arg: 99,
        });
        t
    }

    #[test]
    fn replay_streams_recorded_ops_in_order() {
        let t = mini_trace();
        let mut r = Replay::from_trace(&t, 0, 0);
        assert_eq!(r.len(), 10);
        let (mut asp, _) = world();
        r.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut r, 100);
        assert_eq!(ops.len(), 10);
        assert_eq!(ops[0], WlOp::Store { va: 0x7f00_0000_0000, size: 8 });
        assert_eq!(
            ops[1],
            WlOp::Load { va: 0x7f00_0000_0000 + 64, size: 8 }
        );
        assert_eq!(r.init_data(), vec![(0x7f00_0000_0000, 0xdead_beef)]);
        assert_eq!(r.bytes_moved(), 80);
    }

    #[test]
    fn replay_filters_by_host_and_core() {
        let t = mini_trace();
        let mut other = Replay::from_trace(&t, 1, 0);
        assert_eq!(other.len(), 1);
        assert_eq!(other.next_op(), Some(WlOp::Work { cycles: 99 }));
        assert!(Replay::from_trace(&t, 2, 0).is_empty());
        assert!(Replay::from_trace(&t, 0, 1).is_empty());
    }

    #[test]
    fn for_host_is_dense_over_cores() {
        let mut t = mini_trace();
        // Host 0 also has an event on core 2 but nothing on core 1.
        t.events.push(MemEvent {
            host: 0,
            core: 2,
            op: TraceOp::Work,
            size: 0,
            arg: 1,
        });
        let ws = Replay::for_host(&t, 0);
        assert_eq!(ws.len(), 3); // cores 0..=2, core 1 empty
        assert!(Replay::for_host(&t, 7).is_empty());
    }

    #[test]
    #[should_panic(expected = "trace/config mismatch")]
    fn replay_rejects_wrong_address_space_history() {
        let t = mini_trace();
        let mut r = Replay::from_trace(&t, 0, 0);
        let (mut asp, _) = world();
        // Perturb the mmap cursor so the recorded VA can't come back.
        asp.mmap(4096, MemPolicy::Local { home: 0 });
        r.setup(&mut asp, &MemPolicy::Local { home: 0 });
    }
}
