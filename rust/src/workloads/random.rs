//! Uniform-random access workload (MLC-style loaded-latency driver).
//!
//! Line-granular loads (optionally a write fraction) uniformly over a
//! footprint. Used by the latency-bandwidth characterization bench (E4)
//! and the attach-point ablation (E3): random access defeats both the
//! row-buffer and the LLC, exposing raw memory-path latency.

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::util::rng::Rng;

use super::Workload;

pub struct RandomAccess {
    pub footprint: u64,
    pub ops: u64,
    pub write_frac: f64,
    /// Compute cycles between accesses (0 = back-to-back; higher values
    /// lower offered load for latency-vs-load curves).
    pub gap_cycles: u64,
    base: u64,
    emitted: u64,
    phase_work: bool,
    rng: Rng,
}

impl RandomAccess {
    pub fn new(footprint: u64, ops: u64, write_frac: f64, seed: u64) -> Self {
        assert!(footprint >= 64 && ops > 0);
        RandomAccess {
            footprint,
            ops,
            write_frac,
            gap_cycles: 0,
            base: 0,
            emitted: 0,
            phase_work: false,
            rng: Rng::new(seed),
        }
    }
}

impl Workload for RandomAccess {
    fn name(&self) -> String {
        format!("random-{}MiB", self.footprint >> 20)
    }

    fn setup(&mut self, asp: &mut AddressSpace, policy: &MemPolicy) {
        self.base = asp.mmap(self.footprint, policy.clone());
    }

    fn next_op(&mut self) -> Option<WlOp> {
        if self.emitted >= self.ops {
            return None;
        }
        if self.phase_work && self.gap_cycles > 0 {
            self.phase_work = false;
            return Some(WlOp::Work { cycles: self.gap_cycles });
        }
        self.emitted += 1;
        self.phase_work = true;
        let lines = self.footprint / 64;
        let va = self.base + self.rng.below(lines) * 64;
        if self.rng.chance(self.write_frac) {
            Some(WlOp::Store { va, size: 8 })
        } else {
            Some(WlOp::Load { va, size: 8 })
        }
    }

    fn bytes_moved(&self) -> u64 {
        self.ops * 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{drain, world};

    #[test]
    fn emits_requested_ops_within_footprint() {
        let (mut asp, _) = world();
        let mut w = RandomAccess::new(1 << 20, 100, 0.0, 7);
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let base = match w.next_op().unwrap() {
            WlOp::Load { va, .. } => va,
            _ => panic!(),
        };
        let ops = drain(&mut w, 1000);
        assert_eq!(ops.len(), 99);
        for op in &ops {
            if let WlOp::Load { va, .. } = op {
                assert!(*va >= base - (1 << 20) && *va < base + (1 << 20));
                assert_eq!(va % 64 % 64, va % 64 % 64);
            }
        }
    }

    #[test]
    fn write_fraction_respected() {
        let (mut asp, _) = world();
        let mut w = RandomAccess::new(1 << 20, 2000, 0.5, 3);
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut w, 4000);
        let stores =
            ops.iter().filter(|o| matches!(o, WlOp::Store { .. })).count();
        let frac = stores as f64 / 2000.0;
        assert!((frac - 0.5).abs() < 0.05, "store frac {frac}");
    }

    #[test]
    fn gap_cycles_interleaves_work() {
        let (mut asp, _) = world();
        let mut w = RandomAccess::new(1 << 20, 10, 0.0, 3);
        w.gap_cycles = 5;
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut w, 100);
        let works =
            ops.iter().filter(|o| matches!(o, WlOp::Work { .. })).count();
        assert!(works >= 9);
    }

    #[test]
    fn deterministic_per_seed() {
        let (mut asp, _) = world();
        let mut mk = |seed| {
            let mut w = RandomAccess::new(1 << 20, 50, 0.3, seed);
            w.setup(&mut asp, &MemPolicy::Local { home: 0 });
            drain(&mut w, 200)
        };
        // Note: separate mmaps shift bases, compare shapes not addrs.
        let a = mk(9);
        let b = mk(9);
        assert_eq!(a.len(), b.len());
    }
}
