//! Workload generators.
//!
//! Each generator yields a stream of [`WlOp`]s over virtual addresses
//! inside VMAs it mmap'd at setup time; the system layer translates,
//! times and (for loads/stores) functionally moves the data. STREAM is
//! the paper's characterization workload (§IV); the others drive the
//! ablations and programming-model benches.

pub mod stream;
pub mod random;
pub mod pointer_chase;
pub mod tiered_kv;
pub mod serve;
pub mod replay;

pub use pointer_chase::PointerChase;
pub use random::RandomAccess;
pub use replay::Replay;
pub use serve::{Serve, ServeConfig, TierLru};
pub use stream::{Stream, StreamKernel};
pub use tiered_kv::TieredKv;

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};

/// A stat contribution from a workload (see [`Workload::extra_stats`]).
/// The host merges contributions across its cores at dump time: counts
/// sum, sample sets concatenate before the percentile pass — so a
/// 4-core serving host reports one fleet-wide `serve.p99_ns`, not four
/// per-core ones.
#[derive(Clone, Debug)]
pub enum WlStat {
    /// A plain counter, dumped under its key verbatim.
    Count(u64),
    /// Latency samples in nanoseconds; dumped as exact
    /// `<key>.{p50_ns,p95_ns,p99_ns}` nearest-rank percentiles.
    SamplesNs(Vec<u64>),
}

/// A workload bound to one core.
///
/// `Send` is a supertrait: a workload travels with its [`Host`] onto a
/// worker thread when the parallel event loop (`[sim] threads > 1`)
/// partitions hosts across threads, so every implementor must hold
/// only thread-movable state (plain data, or `Arc`-shared buffers like
/// [`crate::trace::Recorder`]'s).
///
/// [`Host`]: crate::system::Host
pub trait Workload: Send {
    fn name(&self) -> String;

    /// Reserve VMAs under `policy`. Called once before the run.
    fn setup(&mut self, asp: &mut AddressSpace, policy: &MemPolicy);

    /// Next operation, or `None` when finished.
    fn next_op(&mut self) -> Option<WlOp>;

    /// The issue engine's current tick, passed immediately before each
    /// fresh `next_op` pull (not for ops re-issued after an MSHR park).
    /// Request-oriented workloads use the hints to measure per-request
    /// service spans without widening the op interface.
    fn tick_hint(&mut self, _tick: u64) {}

    /// Stats this workload contributes to the host dump (e.g. the
    /// `serve.*` family). Keys are host-relative; contributions with
    /// the same key merge across the host's cores.
    fn extra_stats(&self) -> Vec<(String, WlStat)> {
        Vec::new()
    }

    /// Total bytes the workload intends to move (for bandwidth math).
    fn bytes_moved(&self) -> u64;

    /// Initial memory contents: (va, bits) pairs written functionally
    /// before the timed run (the array-init phase the coordinator can
    /// fast-forward through).
    fn init_data(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }

    /// Functional execution: a load completed with these bits.
    fn load_done(&mut self, _va: u64, _bits: u64) {}

    /// Functional execution: produce the bits a store writes.
    fn store_value(&mut self, _va: u64) -> u64 {
        0
    }

    /// Optional end-of-run functional verification against physical
    /// memory contents (returns Err description on corruption).
    fn verify(
        &self,
        _asp: &mut AddressSpace,
        _alloc: &mut crate::guestos::PageAlloc,
        _mem: &crate::mem::PhysMem,
    ) -> Result<(), String> {
        Ok(())
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::guestos::{NumaNode, PageAlloc};

    /// Drain a workload, returning its ops (with a sanity cap).
    pub fn drain(w: &mut dyn Workload, cap: usize) -> Vec<WlOp> {
        let mut out = Vec::new();
        while let Some(op) = w.next_op() {
            out.push(op);
            assert!(out.len() <= cap, "workload never terminates");
        }
        out
    }

    pub fn world() -> (AddressSpace, PageAlloc) {
        let mut pa = PageAlloc::new(4096);
        pa.add_node(NumaNode::new(0, 0, 256 << 20, true));
        pa.online(0);
        (AddressSpace::new(4096), pa)
    }
}
