//! LLM inference-serving workload: per-request KV-cache churn across
//! DRAM + CXL zNUMA tiers.
//!
//! A fixed simulated-user population issues requests in a Zipf mix (a
//! few users own most of the traffic). Each user's KV context lives in
//! a fixed-size *slot*; a small DRAM arena holds the hot slots and a
//! larger CXL arena holds warm ones, both managed LRU. A request for a
//! DRAM-resident context streams it straight from DRAM; a warm context
//! is streamed from CXL and promoted (demoting the DRAM LRU victim to
//! CXL); a cold miss prefills the context from scratch. Every request
//! then decodes — compute plus an appended KV block. Request latencies
//! (measured via [`Workload::tick_hint`] spans) feed the
//! `serve.p50/p95/p99_ns` percentiles; hit/miss/eviction counters
//! round out the `serve.*` stat family.

use std::collections::VecDeque;

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::util::rng::{Rng, Zipf};

use super::{WlStat, Workload};

/// Knobs for [`Serve`] (the `[workload.serve]` TOML table).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Simulated-user population the Zipf mix draws from.
    pub users: u64,
    /// Zipf exponent of the request mix (0 = uniform).
    pub zipf_s: f64,
    /// Requests to serve per core before finishing.
    pub requests: u64,
    /// Bytes per KV block (multiple of 64).
    pub kv_block: u64,
    /// Blocks per user context; slot size = `kv_block * context_blocks`.
    pub context_blocks: u64,
    /// Hot-tier (DRAM arena) slot count.
    pub dram_slots: usize,
    /// Warm-tier (CXL arena) slot count; 0 disables the warm tier
    /// (demoted contexts are simply dropped).
    pub cxl_slots: usize,
    /// Compute cycles per decoded block.
    pub decode_work: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            users: 512,
            zipf_s: 1.1,
            requests: 500,
            kv_block: 1024,
            context_blocks: 4,
            dram_slots: 64,
            cxl_slots: 256,
            decode_work: 32,
        }
    }
}

/// Fixed-capacity LRU slot cache mapping users to arena slots.
///
/// The eviction machinery behind both serving tiers: `get` touches,
/// `insert` hands out a free slot or recycles the LRU victim's,
/// `remove` frees a slot for reuse. MRU order is maintained explicitly
/// so tier behaviour is deterministic and unit-testable.
#[derive(Clone, Debug)]
pub struct TierLru {
    cap: usize,
    /// (user, slot), LRU at front / MRU at back.
    ents: Vec<(u64, usize)>,
    free: Vec<usize>,
}

impl TierLru {
    pub fn new(cap: usize) -> Self {
        TierLru { cap, ents: Vec::new(), free: (0..cap).rev().collect() }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.ents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ents.is_empty()
    }

    /// Look `user` up; a hit becomes most-recently-used.
    pub fn get(&mut self, user: u64) -> Option<usize> {
        let i = self.ents.iter().position(|&(u, _)| u == user)?;
        let e = self.ents.remove(i);
        let slot = e.1;
        self.ents.push(e);
        Some(slot)
    }

    /// Insert `user`, returning its slot and the evicted `(user, slot)`
    /// if the cache was full (the victim's slot is the one reused).
    /// Inserting a resident user just touches it. Panics when `cap` is
    /// 0 — a zero-capacity tier must not be inserted into.
    pub fn insert(&mut self, user: u64) -> (usize, Option<(u64, usize)>) {
        assert!(self.cap > 0, "insert into zero-capacity tier");
        if let Some(slot) = self.get(user) {
            return (slot, None);
        }
        if let Some(slot) = self.free.pop() {
            self.ents.push((user, slot));
            return (slot, None);
        }
        let victim = self.ents.remove(0); // LRU
        let slot = victim.1;
        self.ents.push((user, slot));
        (slot, Some(victim))
    }

    /// Drop `user`, freeing its slot for a later `insert`.
    pub fn remove(&mut self, user: u64) -> Option<usize> {
        let i = self.ents.iter().position(|&(u, _)| u == user)?;
        let (_, slot) = self.ents.remove(i);
        self.free.push(slot);
        Some(slot)
    }
}

/// The serving workload proper (`[workload] kind = "serve"`).
pub struct Serve {
    cfg: ServeConfig,
    /// Hot-tier arena policy (DRAM-bound; see `PageAlloc::tier_policies`).
    pub hot_policy: MemPolicy,
    /// Warm-tier arena policy (CXL-bound).
    pub cold_policy: MemPolicy,
    rng: Rng,
    zipf: Zipf,
    hot: TierLru,
    warm: TierLru,
    dram_base: u64,
    cxl_base: u64,
    slot_bytes: u64,
    queue: VecDeque<WlOp>,
    reqs_started: u64,
    bytes: u64,
    // Stats.
    tier_hits: u64,
    tier_misses: u64,
    evictions: u64,
    requests_done: u64,
    latencies_ns: Vec<u64>,
    last_tick: u64,
    cur_start: Option<u64>,
}

impl Serve {
    pub fn new(
        cfg: ServeConfig,
        hot_policy: MemPolicy,
        cold_policy: MemPolicy,
        seed: u64,
    ) -> Self {
        assert!(cfg.kv_block >= 64 && cfg.kv_block % 64 == 0);
        assert!(cfg.context_blocks > 0 && cfg.users > 0);
        assert!(cfg.dram_slots > 0);
        let zipf = Zipf::new(cfg.users, cfg.zipf_s);
        let slot_bytes = cfg.kv_block * cfg.context_blocks;
        Serve {
            hot: TierLru::new(cfg.dram_slots),
            warm: TierLru::new(cfg.cxl_slots),
            cfg,
            hot_policy,
            cold_policy,
            rng: Rng::new(seed),
            zipf,
            dram_base: 0,
            cxl_base: 0,
            slot_bytes,
            queue: VecDeque::new(),
            reqs_started: 0,
            bytes: 0,
            tier_hits: 0,
            tier_misses: 0,
            evictions: 0,
            requests_done: 0,
            latencies_ns: Vec::new(),
            last_tick: 0,
            cur_start: None,
        }
    }

    fn dram_addr(&self, slot: usize) -> u64 {
        self.dram_base + slot as u64 * self.slot_bytes
    }

    fn cxl_addr(&self, slot: usize) -> u64 {
        self.cxl_base + slot as u64 * self.slot_bytes
    }

    /// Queue a 64B-line sweep over `[base, base+len)`.
    fn push_lines(&mut self, base: u64, len: u64, store: bool) {
        for off in (0..len).step_by(64) {
            let va = base + off;
            self.queue.push_back(if store {
                WlOp::Store { va, size: 8 }
            } else {
                WlOp::Load { va, size: 8 }
            });
        }
        self.bytes += len;
    }

    /// Land `user` in a hot slot, demoting the DRAM LRU victim to the
    /// warm tier (or dropping it when the warm tier is absent).
    fn promote(&mut self, user: u64) -> usize {
        let (slot, victim) = self.hot.insert(user);
        if let Some((victim_user, victim_slot)) = victim {
            self.evictions += 1;
            if self.warm.cap() > 0 {
                let (wslot, dropped) = self.warm.insert(victim_user);
                // Write the victim's context out to CXL. Whoever
                // `dropped` names loses its warm copy silently.
                let _ = dropped;
                let (base, len) = (self.cxl_addr(wslot), self.slot_bytes);
                self.push_lines(base, len, true);
            }
            let _ = victim_slot; // == slot (the LRU victim's slot is reused)
        }
        slot
    }

    /// Generate the full op stream for one request.
    fn gen_request(&mut self) {
        let user = self.zipf.sample(&mut self.rng);
        let dram_slot = if let Some(slot) = self.hot.get(user) {
            // Hot: context streams straight from DRAM.
            self.tier_hits += 1;
            let (base, len) = (self.dram_addr(slot), self.slot_bytes);
            self.push_lines(base, len, false);
            slot
        } else if let Some(wslot) = self.warm.remove(user) {
            // Warm: stream from CXL, then promote into DRAM.
            self.tier_hits += 1;
            let (base, len) = (self.cxl_addr(wslot), self.slot_bytes);
            self.push_lines(base, len, false);
            let slot = self.promote(user);
            let (base, len) = (self.dram_addr(slot), self.slot_bytes);
            self.push_lines(base, len, true);
            slot
        } else {
            // Cold miss: prefill the whole context into DRAM.
            self.tier_misses += 1;
            let slot = self.promote(user);
            self.queue.push_back(WlOp::Work {
                cycles: self.cfg.decode_work * self.cfg.context_blocks,
            });
            let (base, len) = (self.dram_addr(slot), self.slot_bytes);
            self.push_lines(base, len, true);
            slot
        };
        // Decode: compute, then append one KV block (ring position).
        self.queue.push_back(WlOp::Work { cycles: self.cfg.decode_work });
        let blk = self.reqs_started % self.cfg.context_blocks;
        let base = self.dram_addr(dram_slot) + blk * self.cfg.kv_block;
        self.push_lines(base, self.cfg.kv_block, true);
    }
}

impl Workload for Serve {
    fn name(&self) -> String {
        format!("serve-{}u", self.cfg.users)
    }

    fn setup(&mut self, asp: &mut AddressSpace, _policy: &MemPolicy) {
        // Tier arenas override the run-wide default policy — the
        // DRAM/CXL split IS the workload's placement decision.
        self.dram_base = asp.mmap(
            self.cfg.dram_slots as u64 * self.slot_bytes,
            self.hot_policy.clone(),
        );
        if self.cfg.cxl_slots > 0 {
            self.cxl_base = asp.mmap(
                self.cfg.cxl_slots as u64 * self.slot_bytes,
                self.cold_policy.clone(),
            );
        }
    }

    fn next_op(&mut self) -> Option<WlOp> {
        if self.queue.is_empty() {
            // Request boundary: the tick_hint just before this pull
            // closes the previous request's service span.
            if let Some(start) = self.cur_start.take() {
                self.latencies_ns
                    .push(self.last_tick.saturating_sub(start) / 1000);
                self.requests_done += 1;
            }
            if self.reqs_started >= self.cfg.requests {
                return None;
            }
            self.cur_start = Some(self.last_tick);
            self.gen_request();
            self.reqs_started += 1;
        }
        self.queue.pop_front()
    }

    fn tick_hint(&mut self, tick: u64) {
        self.last_tick = tick;
    }

    fn extra_stats(&self) -> Vec<(String, WlStat)> {
        vec![
            ("serve.requests".into(), WlStat::Count(self.requests_done)),
            ("serve.tier_hits".into(), WlStat::Count(self.tier_hits)),
            ("serve.tier_misses".into(), WlStat::Count(self.tier_misses)),
            ("serve.evictions".into(), WlStat::Count(self.evictions)),
            ("serve".into(), WlStat::SamplesNs(self.latencies_ns.clone())),
        ]
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{drain, world};

    fn small_cfg() -> ServeConfig {
        ServeConfig {
            users: 64,
            zipf_s: 1.1,
            requests: 60,
            kv_block: 256,
            context_blocks: 2,
            dram_slots: 8,
            cxl_slots: 16,
            decode_work: 16,
        }
    }

    fn local(home: u32) -> MemPolicy {
        MemPolicy::Local { home }
    }

    // ---- TierLru eviction machinery ------------------------------------

    #[test]
    fn lru_insert_fills_then_evicts_in_lru_order() {
        let mut t = TierLru::new(2);
        let (s0, e0) = t.insert(10);
        let (s1, e1) = t.insert(11);
        assert!(e0.is_none() && e1.is_none());
        assert_ne!(s0, s1);
        // 10 is now LRU; inserting 12 evicts it and reuses its slot.
        let (s2, e2) = t.insert(12);
        assert_eq!(e2, Some((10, s0)));
        assert_eq!(s2, s0);
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn lru_get_touches_recency() {
        let mut t = TierLru::new(2);
        t.insert(1);
        t.insert(2);
        assert_eq!(t.get(1), Some(t.get(1).unwrap()));
        // 1 was touched, so 2 is now the victim.
        let (_, ev) = t.insert(3);
        assert_eq!(ev.map(|(u, _)| u), Some(2));
        assert!(t.get(1).is_some());
        assert!(t.get(2).is_none());
    }

    #[test]
    fn lru_remove_frees_slot_for_reuse() {
        let mut t = TierLru::new(1);
        let (s, _) = t.insert(5);
        assert_eq!(t.remove(5), Some(s));
        assert!(t.is_empty());
        assert_eq!(t.remove(5), None);
        // Freed slot comes back without an eviction.
        let (s2, ev) = t.insert(6);
        assert_eq!(s2, s);
        assert!(ev.is_none());
    }

    #[test]
    fn lru_insert_resident_user_is_a_touch() {
        let mut t = TierLru::new(2);
        let (s, _) = t.insert(7);
        t.insert(8);
        let (s2, ev) = t.insert(7); // already resident
        assert_eq!(s2, s);
        assert!(ev.is_none());
        assert_eq!(t.len(), 2);
        // 8 is now LRU.
        let (_, ev) = t.insert(9);
        assert_eq!(ev.map(|(u, _)| u), Some(8));
    }

    #[test]
    #[should_panic(expected = "zero-capacity")]
    fn lru_zero_capacity_insert_panics() {
        TierLru::new(0).insert(1);
    }

    #[test]
    fn lru_single_slot_thrash() {
        let mut t = TierLru::new(1);
        let (s0, _) = t.insert(1);
        for u in 2..10u64 {
            let (s, ev) = t.insert(u);
            assert_eq!(s, s0, "single slot always reused");
            assert_eq!(ev.map(|(v, _)| v), Some(u - 1));
        }
    }

    // ---- Serve op stream -----------------------------------------------

    #[test]
    fn serve_ops_stay_inside_arenas() {
        let (mut asp, _) = world();
        let mut w = Serve::new(small_cfg(), local(0), local(0), 7);
        w.setup(&mut asp, &local(0));
        let dram_lo = w.dram_base;
        let dram_hi = dram_lo + w.cfg.dram_slots as u64 * w.slot_bytes;
        let cxl_lo = w.cxl_base;
        let cxl_hi = cxl_lo + w.cfg.cxl_slots as u64 * w.slot_bytes;
        let ops = drain(&mut w, 200_000);
        assert!(!ops.is_empty());
        for op in &ops {
            if let WlOp::Load { va, .. } | WlOp::Store { va, .. } = op {
                let in_dram = *va >= dram_lo && *va < dram_hi;
                let in_cxl = *va >= cxl_lo && *va < cxl_hi;
                assert!(in_dram || in_cxl, "op outside arenas: {va:#x}");
            }
        }
        assert_eq!(w.tier_hits + w.tier_misses, w.cfg.requests);
        assert!(w.tier_misses >= (w.cfg.dram_slots as u64).min(w.cfg.requests));
    }

    #[test]
    fn serve_zipf_mix_hits_after_warmup() {
        let (mut asp, _) = world();
        let mut cfg = small_cfg();
        cfg.requests = 400;
        let mut w = Serve::new(cfg, local(0), local(0), 11);
        w.setup(&mut asp, &local(0));
        drain(&mut w, 2_000_000);
        // Zipf skew means the popular users' contexts stay resident.
        assert!(w.tier_hits > 0, "no tier hits at all");
        assert!(w.evictions > 0, "hot tier never churned");
    }

    #[test]
    fn serve_latency_spans_via_tick_hints() {
        let (mut asp, _) = world();
        let mut cfg = small_cfg();
        cfg.requests = 3;
        let mut w = Serve::new(cfg, local(0), local(0), 13);
        w.setup(&mut asp, &local(0));
        // Issue-engine shape: hint (monotonic tick), then pull.
        let mut tick = 0u64;
        loop {
            w.tick_hint(tick);
            if w.next_op().is_none() {
                break;
            }
            tick += 2_000; // 2 ns per op
        }
        assert_eq!(w.requests_done, 3);
        assert_eq!(w.latencies_ns.len(), 3);
        // Spans measured in ns (ticks/1000), all non-zero here.
        assert!(w.latencies_ns.iter().all(|&l| l > 0));
        let stats = w.extra_stats();
        assert!(stats.iter().any(|(k, _)| k == "serve"));
    }

    #[test]
    fn serve_no_warm_tier_drops_demotions() {
        let (mut asp, _) = world();
        let mut cfg = small_cfg();
        cfg.cxl_slots = 0;
        cfg.requests = 200;
        let mut w = Serve::new(cfg, local(0), local(0), 17);
        w.setup(&mut asp, &local(0));
        assert_eq!(w.cxl_base, 0, "no warm arena mapped");
        let ops = drain(&mut w, 2_000_000);
        let dram_hi = w.dram_base + w.cfg.dram_slots as u64 * w.slot_bytes;
        for op in &ops {
            if let WlOp::Load { va, .. } | WlOp::Store { va, .. } = op {
                assert!(
                    *va >= w.dram_base && *va < dram_hi,
                    "op left the DRAM arena with cxl_slots=0"
                );
            }
        }
        assert!(w.evictions > 0);
    }

    #[test]
    fn serve_deterministic_for_seed() {
        let run = || {
            let (mut asp, _) = world();
            let mut w = Serve::new(small_cfg(), local(0), local(0), 23);
            w.setup(&mut asp, &local(0));
            drain(&mut w, 2_000_000)
        };
        assert_eq!(run(), run());
    }
}
