//! Tiered key-value workload — the LLM-KV-cache-shaped motivation from
//! the paper's introduction ("distribute the KV-cache across several
//! nodes when it does not fit a single server instance").
//!
//! `entries` fixed-size values; a Zipf-like hot set served from a
//! DRAM-bound VMA and a cold majority on a CXL-bound VMA (the tiering
//! decision a real KV layer would take). GETs dominate, PUTs rewrite
//! values. Used by the programming-model bench (E5).

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::util::rng::Rng;

use super::Workload;

pub struct TieredKv {
    pub entries: u64,
    pub value_bytes: u64,
    pub ops: u64,
    pub hot_frac: f64,
    pub hot_hit_prob: f64,
    pub put_frac: f64,
    /// Policies for the two tiers (set before `setup`).
    pub hot_policy: MemPolicy,
    pub cold_policy: MemPolicy,
    hot_base: u64,
    cold_base: u64,
    emitted: u64,
    in_value: u64, // remaining lines of current value access
    cur_va: u64,
    cur_store: bool,
    rng: Rng,
}

impl TieredKv {
    pub fn new(entries: u64, value_bytes: u64, ops: u64, seed: u64) -> Self {
        assert!(value_bytes % 64 == 0 && value_bytes >= 64);
        assert!(entries > 0, "tiered-kv needs at least one entry");
        TieredKv {
            entries,
            value_bytes,
            ops,
            hot_frac: 0.1,
            hot_hit_prob: 0.8,
            put_frac: 0.1,
            hot_policy: MemPolicy::Bind { nodes: vec![0] },
            cold_policy: MemPolicy::Bind { nodes: vec![1] },
            hot_base: 0,
            cold_base: 0,
            emitted: 0,
            in_value: 0,
            cur_va: 0,
            cur_store: false,
            rng: Rng::new(seed),
        }
    }

    fn hot_entries(&self) -> u64 {
        // Clamp to the population: hot_frac >= 1.0 means everything is
        // hot (and the cold tier is empty, never sampled).
        ((self.entries as f64 * self.hot_frac) as u64)
            .max(1)
            .min(self.entries)
    }
}

impl Workload for TieredKv {
    fn name(&self) -> String {
        format!("tiered-kv-{}e", self.entries)
    }

    fn setup(&mut self, asp: &mut AddressSpace, _policy: &MemPolicy) {
        // The workload's own tier policies deliberately override the
        // run-wide default — tiering IS the policy decision here.
        let hot = self.hot_entries();
        self.hot_base =
            asp.mmap(hot * self.value_bytes, self.hot_policy.clone());
        self.cold_base = asp
            .mmap((self.entries - hot) * self.value_bytes, self.cold_policy.clone());
    }

    fn next_op(&mut self) -> Option<WlOp> {
        // Stream the lines of the current value first.
        if self.in_value > 0 {
            self.in_value -= 1;
            let va = self.cur_va;
            self.cur_va += 64;
            return Some(if self.cur_store {
                WlOp::Store { va, size: 8 }
            } else {
                WlOp::Load { va, size: 8 }
            });
        }
        if self.emitted >= self.ops {
            return None;
        }
        self.emitted += 1;
        // An empty cold tier (hot_frac >= 1.0, or a single entry) must
        // never be sampled — `below(0)` is undefined and the cold VMA
        // is zero-length.
        let cold_entries = self.entries - self.hot_entries();
        let hot = cold_entries == 0 || self.rng.chance(self.hot_hit_prob);
        let (base, count) = if hot {
            (self.hot_base, self.hot_entries())
        } else {
            (self.cold_base, self.entries - self.hot_entries())
        };
        let key = self.rng.below(count);
        self.cur_va = base + key * self.value_bytes;
        self.cur_store = self.rng.chance(self.put_frac);
        self.in_value = self.value_bytes / 64;
        self.next_op()
    }

    fn bytes_moved(&self) -> u64 {
        self.ops * self.value_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{drain, world};

    #[test]
    fn values_stream_whole_lines() {
        let (mut asp, _) = world();
        let mut w = TieredKv::new(100, 256, 10, 1);
        w.hot_policy = MemPolicy::Local { home: 0 };
        w.cold_policy = MemPolicy::Local { home: 0 };
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut w, 1000);
        // 10 ops x 4 lines each.
        assert_eq!(ops.len(), 40);
    }

    #[test]
    fn hot_set_dominates_accesses() {
        let (mut asp, _) = world();
        let mut w = TieredKv::new(1000, 64, 2000, 2);
        w.hot_policy = MemPolicy::Local { home: 0 };
        w.cold_policy = MemPolicy::Local { home: 0 };
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let hot_lo = w.hot_base;
        let hot_hi = hot_lo + w.hot_entries() * 64;
        let ops = drain(&mut w, 10_000);
        let hot_hits = ops
            .iter()
            .filter(|o| match o {
                WlOp::Load { va, .. } | WlOp::Store { va, .. } => {
                    *va >= hot_lo && *va < hot_hi
                }
                _ => false,
            })
            .count();
        let frac = hot_hits as f64 / ops.len() as f64;
        assert!((frac - 0.8).abs() < 0.05, "hot frac {frac}");
    }

    #[test]
    fn all_hot_population_never_touches_cold_tier() {
        // hot_frac = 1.0 used to sample `below(0)` and then access the
        // zero-length cold VMA; now everything must stay in the hot VMA.
        let (mut asp, _) = world();
        let mut w = TieredKv::new(32, 64, 500, 5);
        w.hot_frac = 1.0;
        w.hot_policy = MemPolicy::Local { home: 0 };
        w.cold_policy = MemPolicy::Local { home: 0 };
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        assert_eq!(w.hot_entries(), 32);
        let hot_lo = w.hot_base;
        let hot_hi = hot_lo + 32 * 64;
        let ops = drain(&mut w, 5_000);
        assert_eq!(ops.len(), 500);
        for op in &ops {
            if let WlOp::Load { va, .. } | WlOp::Store { va, .. } = op {
                assert!(
                    *va >= hot_lo && *va < hot_hi,
                    "op escaped the hot VMA: {va:#x}"
                );
            }
        }
    }

    #[test]
    fn single_entry_population_is_all_hot() {
        let (mut asp, _) = world();
        let mut w = TieredKv::new(1, 64, 50, 6);
        w.hot_policy = MemPolicy::Local { home: 0 };
        w.cold_policy = MemPolicy::Local { home: 0 };
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        // hot_entries().max(1) == entries: cold tier is empty.
        assert_eq!(w.hot_entries(), 1);
        let ops = drain(&mut w, 500);
        assert_eq!(ops.len(), 50);
        for op in &ops {
            if let WlOp::Load { va, .. } | WlOp::Store { va, .. } = op {
                assert_eq!(*va, w.hot_base, "only one 64B value exists");
            }
        }
    }

    #[test]
    fn overlarge_hot_frac_clamps_to_population() {
        let mut w = TieredKv::new(10, 64, 1, 7);
        w.hot_frac = 3.5;
        assert_eq!(w.hot_entries(), 10, "hot set clamps at the population");
    }

    #[test]
    fn put_fraction_approximate() {
        let (mut asp, _) = world();
        let mut w = TieredKv::new(500, 64, 3000, 3);
        w.hot_policy = MemPolicy::Local { home: 0 };
        w.cold_policy = MemPolicy::Local { home: 0 };
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut w, 20_000);
        let stores =
            ops.iter().filter(|o| matches!(o, WlOp::Store { .. })).count();
        let frac = stores as f64 / ops.len() as f64;
        assert!((frac - 0.1).abs() < 0.03, "put frac {frac}");
    }
}
