//! Pointer-chase workload: fully dependent loads.
//!
//! A random Hamiltonian cycle over `nodes` cache lines; each load's
//! address is the previous load's value, so memory-level parallelism is
//! exactly 1 regardless of CPU model. This isolates the *unloaded*
//! latency of the memory class it lands on — the classic idle-latency
//! probe for CXL-vs-DRAM comparisons.

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};
use crate::util::rng::Rng;

use super::Workload;

pub struct PointerChase {
    pub nodes: u64,
    pub hops: u64,
    base: u64,
    /// The cycle's successor table (index -> next index), fixed at
    /// construction so runs are reproducible.
    order: Vec<u64>,
    cur: u64,
    emitted: u64,
}

impl PointerChase {
    pub fn new(nodes: u64, hops: u64, seed: u64) -> Self {
        assert!(nodes >= 2);
        // Build a random cycle: shuffle 1..n then close the loop.
        let mut rng = Rng::new(seed);
        let mut perm: Vec<u64> = (0..nodes).collect();
        rng.shuffle(&mut perm);
        let mut order = vec![0u64; nodes as usize];
        for w in perm.windows(2) {
            order[w[0] as usize] = w[1];
        }
        order[perm[nodes as usize - 1] as usize] = perm[0];
        PointerChase { nodes, hops, base: 0, order, cur: 0, emitted: 0 }
    }

    /// The VA of node `i` (one per cache line).
    fn node_va(&self, i: u64) -> u64 {
        self.base + i * 64
    }

    /// The successor chain as (va, next_va) pairs — used by the system
    /// layer to initialize memory so the chase is functionally real.
    pub fn pointer_inits(&self) -> Vec<(u64, u64)> {
        (0..self.nodes)
            .map(|i| (self.node_va(i), self.node_va(self.order[i as usize])))
            .collect()
    }
}

impl Workload for PointerChase {
    fn name(&self) -> String {
        format!("chase-{}n", self.nodes)
    }

    fn setup(&mut self, asp: &mut AddressSpace, policy: &MemPolicy) {
        self.base = asp.mmap(self.nodes * 64, policy.clone());
        self.cur = 0;
    }

    fn next_op(&mut self) -> Option<WlOp> {
        if self.emitted >= self.hops {
            return None;
        }
        self.emitted += 1;
        let va = self.node_va(self.cur);
        self.cur = self.order[self.cur as usize];
        Some(WlOp::Load { va, size: 8 })
    }

    fn bytes_moved(&self) -> u64 {
        self.hops * 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{drain, world};

    #[test]
    fn chase_visits_all_nodes_once_per_cycle() {
        let (mut asp, _) = world();
        let mut w = PointerChase::new(16, 16, 1);
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut w, 64);
        let mut seen = std::collections::HashSet::new();
        for op in &ops {
            if let WlOp::Load { va, .. } = op {
                seen.insert(*va);
            }
        }
        assert_eq!(seen.len(), 16, "must be a Hamiltonian cycle");
    }

    #[test]
    fn successor_table_is_permutation() {
        let w = PointerChase::new(64, 1, 5);
        let mut targets: Vec<u64> = w.order.clone();
        targets.sort_unstable();
        assert_eq!(targets, (0..64).collect::<Vec<_>>());
        // No self-loop.
        assert!(w.order.iter().enumerate().all(|(i, &n)| i as u64 != n));
    }

    #[test]
    fn inits_match_order() {
        let (mut asp, _) = world();
        let mut w = PointerChase::new(8, 8, 2);
        w.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let inits = w.pointer_inits();
        assert_eq!(inits.len(), 8);
        for (va, next) in inits {
            assert_eq!((va - w.base) % 64, 0);
            assert_eq!((next - w.base) % 64, 0);
        }
    }
}
