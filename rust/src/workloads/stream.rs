//! STREAM (McCalpin) — the paper's characterization micro-benchmark.
//!
//! Four kernels over three f64 arrays of `n` elements:
//!   Copy:  c[i] = a[i]             (16 B/iter moved)
//!   Scale: b[i] = s * c[i]         (16 B/iter)
//!   Add:   c[i] = a[i] + b[i]      (24 B/iter)
//!   Triad: a[i] = b[i] + s * c[i]  (24 B/iter)
//!
//! The paper runs STREAM at working sets of 2/4/6/8x the L2 size to
//! stress the CXL memory (§IV); `Stream::for_wss` builds exactly that.
//! Stores are preceded by the loads the kernel semantics require, and a
//! small `Work` op models the FP pipeline between iterations.

use crate::cpu::WlOp;
use crate::guestos::{AddressSpace, MemPolicy};

use super::Workload;

pub const SCALAR: f64 = 3.0;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamKernel {
    Copy,
    Scale,
    Add,
    Triad,
}

impl StreamKernel {
    pub fn name(&self) -> &'static str {
        match self {
            StreamKernel::Copy => "copy",
            StreamKernel::Scale => "scale",
            StreamKernel::Add => "add",
            StreamKernel::Triad => "triad",
        }
    }

    pub fn all() -> [StreamKernel; 4] {
        [
            StreamKernel::Copy,
            StreamKernel::Scale,
            StreamKernel::Add,
            StreamKernel::Triad,
        ]
    }

    /// Bytes moved per iteration (loads + stores of f64).
    pub fn bytes_per_iter(&self) -> u64 {
        match self {
            StreamKernel::Copy | StreamKernel::Scale => 16,
            StreamKernel::Add | StreamKernel::Triad => 24,
        }
    }
}

pub struct Stream {
    pub kernel: StreamKernel,
    pub n: u64,
    /// Iterations of the kernel (STREAM's NTIMES; default 1 pass for
    /// simulation-speed reasons, sweeps override).
    pub passes: u32,
    a: u64,
    b: u64,
    c: u64,
    i: u64,
    pass: u32,
    phase: u8,
    /// Compute cycles charged between iterations.
    pub work_cycles: u64,
    /// Operand latches for functional execution (program order).
    op1: f64,
    op2: f64,
}

impl Stream {
    pub fn new(kernel: StreamKernel, n: u64, passes: u32) -> Self {
        assert!(n > 0 && passes > 0);
        Stream {
            kernel,
            n,
            passes,
            a: 0,
            b: 0,
            c: 0,
            i: 0,
            pass: 0,
            phase: 0,
            work_cycles: 2,
            op1: 0.0,
            op2: 0.0,
        }
    }

    /// Working set = `mult` x l2_size across the three arrays.
    ///
    /// Two passes (STREAM's NTIMES spirit): the first streams cold, the
    /// second exposes the capacity effect Fig. 5 plots — it re-hits the
    /// LLC when WSS fits and misses again when WSS >> L2.
    pub fn for_wss(kernel: StreamKernel, l2_size: u64, mult: u64) -> Self {
        let total = l2_size * mult;
        let n = total / (3 * 8);
        Stream::new(kernel, n.max(64), 2)
    }

    pub fn array_bytes(&self) -> u64 {
        self.n * 8
    }

    fn idx(&self, base: u64) -> u64 {
        base + self.i * 8
    }
}

impl Workload for Stream {
    fn name(&self) -> String {
        format!("stream-{}-n{}", self.kernel.name(), self.n)
    }

    fn setup(&mut self, asp: &mut AddressSpace, policy: &MemPolicy) {
        self.a = asp.mmap(self.array_bytes(), policy.clone());
        self.b = asp.mmap(self.array_bytes(), policy.clone());
        self.c = asp.mmap(self.array_bytes(), policy.clone());
    }

    fn next_op(&mut self) -> Option<WlOp> {
        if self.pass >= self.passes {
            return None;
        }
        // Phase machine per iteration: loads -> store -> work.
        use StreamKernel::*;
        let op = match (self.kernel, self.phase) {
            (Copy, 0) => WlOp::Load { va: self.idx(self.a), size: 8 },
            (Copy, 1) => WlOp::Store { va: self.idx(self.c), size: 8 },
            (Scale, 0) => WlOp::Load { va: self.idx(self.c), size: 8 },
            (Scale, 1) => WlOp::Store { va: self.idx(self.b), size: 8 },
            (Add, 0) => WlOp::Load { va: self.idx(self.a), size: 8 },
            (Add, 1) => WlOp::Load { va: self.idx(self.b), size: 8 },
            (Add, 2) => WlOp::Store { va: self.idx(self.c), size: 8 },
            (Triad, 0) => WlOp::Load { va: self.idx(self.b), size: 8 },
            (Triad, 1) => WlOp::Load { va: self.idx(self.c), size: 8 },
            (Triad, 2) => WlOp::Store { va: self.idx(self.a), size: 8 },
            (_, p) => {
                debug_assert_eq!(p, self.final_phase());
                let w = WlOp::Work { cycles: self.work_cycles };
                self.phase = 0;
                self.i += 1;
                if self.i == self.n {
                    self.i = 0;
                    self.pass += 1;
                }
                return Some(w);
            }
        };
        self.phase += 1;
        Some(op)
    }

    fn bytes_moved(&self) -> u64 {
        self.kernel.bytes_per_iter() * self.n * self.passes as u64
    }

    fn init_data(&self) -> Vec<(u64, u64)> {
        // STREAM's canonical values (a=1.0, b=2.0, c=0.0), but only the
        // arrays this kernel READS are initialized: the destination is
        // fully overwritten before it is ever read, so pre-faulting it
        // would only distort first-touch placement — destination pages
        // fault in DURING the timed run under the workload's policy
        // (which is what lets memory hot-added mid-run actually receive
        // pages; see examples/rebind_sweep.rs).
        use StreamKernel::*;
        let src: Vec<(u64, f64)> = match self.kernel {
            Copy => vec![(self.a, 1.0)],
            Scale => vec![(self.c, 0.0)],
            Add => vec![(self.a, 1.0), (self.b, 2.0)],
            Triad => vec![(self.b, 2.0), (self.c, 0.0)],
        };
        let mut v = Vec::with_capacity(src.len() * self.n as usize);
        for (base, val) in src {
            for i in 0..self.n {
                v.push((base + i * 8, val.to_bits()));
            }
        }
        v
    }

    fn load_done(&mut self, _va: u64, bits: u64) {
        // Operands arrive in phase order; shift the latch chain.
        self.op2 = self.op1;
        self.op1 = f64::from_bits(bits);
    }

    fn store_value(&mut self, _va: u64) -> u64 {
        use StreamKernel::*;
        let v = match self.kernel {
            Copy => self.op1,
            Scale => SCALAR * self.op1,
            // op2 holds the first load, op1 the second.
            Add => self.op2 + self.op1,
            Triad => self.op2 + SCALAR * self.op1,
        };
        v.to_bits()
    }

    fn verify(
        &self,
        asp: &mut AddressSpace,
        alloc: &mut crate::guestos::PageAlloc,
        mem: &crate::mem::PhysMem,
    ) -> Result<(), String> {
        use StreamKernel::*;
        // After `passes` runs from the canonical init, the destination
        // array holds a closed-form value (each pass recomputes from the
        // same sources, so passes > 1 are idempotent for Copy/Scale/Add;
        // Triad feeds back into a).
        let (arr, expect): (u64, Box<dyn Fn(u32) -> f64>) = match self.kernel {
            Copy => (self.c, Box::new(|_| 1.0)),
            Scale => (self.b, Box::new(|_| SCALAR * 0.0)),
            Add => (self.c, Box::new(|_| 1.0 + 2.0)),
            Triad => (
                self.a,
                Box::new(|p| {
                    // a_{k+1} = b + s*c, b=2, c=0 constant => a=2 after
                    // one pass and stays 2.
                    let _ = p;
                    2.0
                }),
            ),
        };
        // Scale reads c (0.0) so b becomes 0; Copy writes c=1.
        for i in (0..self.n).step_by((self.n / 16).max(1) as usize) {
            let va = arr + i * 8;
            let pa = asp
                .translate(va, alloc)
                .map_err(|e| format!("verify translate: {e}"))?;
            let got = mem.read_f64(pa);
            let want = expect(self.passes);
            if (got - want).abs() > 1e-12 {
                return Err(format!(
                    "stream {} verify failed at [{}]: got {got}, want {want}",
                    self.kernel.name(),
                    i
                ));
            }
        }
        Ok(())
    }
}

impl Stream {
    fn final_phase(&self) -> u8 {
        match self.kernel {
            StreamKernel::Copy | StreamKernel::Scale => 2,
            StreamKernel::Add | StreamKernel::Triad => 3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::testutil::{drain, world};

    #[test]
    fn copy_emits_load_store_work_per_iter() {
        let (mut asp, _) = world();
        let mut s = Stream::new(StreamKernel::Copy, 4, 1);
        s.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut s, 100);
        assert_eq!(ops.len(), 3 * 4);
        assert!(matches!(ops[0], WlOp::Load { .. }));
        assert!(matches!(ops[1], WlOp::Store { .. }));
        assert!(matches!(ops[2], WlOp::Work { .. }));
    }

    #[test]
    fn triad_two_loads_one_store() {
        let (mut asp, _) = world();
        let mut s = Stream::new(StreamKernel::Triad, 2, 1);
        s.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut s, 100);
        let loads = ops.iter().filter(|o| matches!(o, WlOp::Load { .. })).count();
        let stores =
            ops.iter().filter(|o| matches!(o, WlOp::Store { .. })).count();
        assert_eq!(loads, 4);
        assert_eq!(stores, 2);
    }

    #[test]
    fn wss_sizing_matches_multiplier() {
        let l2 = 1u64 << 20;
        for mult in [2u64, 4, 6, 8] {
            let s = Stream::for_wss(StreamKernel::Copy, l2, mult);
            let total = 3 * s.array_bytes();
            let target = l2 * mult;
            assert!(
                (total as i64 - target as i64).unsigned_abs() < 64,
                "wss {total} vs target {target}"
            );
        }
    }

    #[test]
    fn addresses_stride_sequentially() {
        let (mut asp, _) = world();
        let mut s = Stream::new(StreamKernel::Copy, 3, 1);
        s.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut s, 100);
        let loads: Vec<u64> = ops
            .iter()
            .filter_map(|o| match o {
                WlOp::Load { va, .. } => Some(*va),
                _ => None,
            })
            .collect();
        assert_eq!(loads[1] - loads[0], 8);
        assert_eq!(loads[2] - loads[1], 8);
    }

    #[test]
    fn multi_pass_repeats() {
        let (mut asp, _) = world();
        let mut s = Stream::new(StreamKernel::Scale, 2, 3);
        s.setup(&mut asp, &MemPolicy::Local { home: 0 });
        let ops = drain(&mut s, 100);
        assert_eq!(ops.len(), 3 * 2 * 3);
        assert_eq!(s.bytes_moved(), 16 * 2 * 3);
    }
}
