//! ACPI table builders — real binary layouts with checksums.
//!
//! The guest OS model parses these *bytes* (signature, length, checksum,
//! field offsets per the ACPI 6.5 spec), exactly as Linux would; nothing
//! is passed out-of-band. Tables produced: RSDP, XSDT, FADT (DSDT
//! pointer), MADT, MCFG, SRAT and the CXL 2.0 CEDT (CHBS + CFMWS).

/// Compute the value that makes the byte sum zero.
pub fn checksum_fix(bytes: &[u8], at: usize) -> u8 {
    let sum: u8 = bytes
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != at)
        .fold(0u8, |a, (_, b)| a.wrapping_add(*b));
    0u8.wrapping_sub(sum)
}

pub fn table_checksum_ok(bytes: &[u8]) -> bool {
    bytes.iter().fold(0u8, |a, b| a.wrapping_add(*b)) == 0
}

/// Standard 36-byte SDT header; returns the full table with checksum.
pub fn sdt(signature: &[u8; 4], revision: u8, payload: &[u8]) -> Vec<u8> {
    let len = 36 + payload.len();
    let mut t = Vec::with_capacity(len);
    t.extend_from_slice(signature);
    t.extend_from_slice(&(len as u32).to_le_bytes());
    t.push(revision);
    t.push(0); // checksum placeholder
    t.extend_from_slice(b"CXLRS "); // OEMID (6)
    t.extend_from_slice(b"RAMSIM  "); // OEM table id (8)
    t.extend_from_slice(&1u32.to_le_bytes()); // OEM revision
    t.extend_from_slice(b"CSIM"); // creator id
    t.extend_from_slice(&1u32.to_le_bytes()); // creator revision
    t.extend_from_slice(payload);
    let c = checksum_fix(&t, 9);
    t[9] = c;
    t
}

/// RSDP v2 (36 bytes) pointing at the XSDT.
pub fn rsdp(xsdt_addr: u64) -> Vec<u8> {
    let mut r = Vec::with_capacity(36);
    r.extend_from_slice(b"RSD PTR "); // signature (8)
    r.push(0); // checksum placeholder (covers first 20 bytes)
    r.extend_from_slice(b"CXLRS "); // OEMID
    r.push(2); // revision: ACPI 2.0+
    r.extend_from_slice(&0u32.to_le_bytes()); // rsdt (legacy, unused)
    r.extend_from_slice(&36u32.to_le_bytes()); // length
    r.extend_from_slice(&xsdt_addr.to_le_bytes());
    r.push(0); // extended checksum placeholder
    r.extend_from_slice(&[0u8; 3]); // reserved
    let c20 = checksum_fix(&r[..20], 8);
    r[8] = c20;
    let cext = checksum_fix(&r, 32);
    r[32] = cext;
    r
}

/// XSDT: array of 64-bit table pointers.
pub fn xsdt(entries: &[u64]) -> Vec<u8> {
    let mut p = Vec::with_capacity(entries.len() * 8);
    for e in entries {
        p.extend_from_slice(&e.to_le_bytes());
    }
    sdt(b"XSDT", 1, &p)
}

/// FADT carrying the DSDT pointer (fields we need: DSDT @36, X_DSDT
/// @140; table padded to 276 bytes of ACPI 6 FADT).
pub fn fadt(dsdt_addr: u64) -> Vec<u8> {
    let mut p = vec![0u8; 276 - 36];
    // offset within payload = absolute - 36.
    p[0..4].copy_from_slice(&(dsdt_addr as u32).to_le_bytes()); // DSDT
    p[140 - 36..148 - 36].copy_from_slice(&dsdt_addr.to_le_bytes()); // X_DSDT
    sdt(b"FACP", 6, &p)
}

/// MADT: local-APIC base + one Processor Local APIC entry per core.
pub fn madt(cores: usize) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&0xFEE0_0000u32.to_le_bytes()); // local APIC addr
    p.extend_from_slice(&1u32.to_le_bytes()); // flags: PC-AT compatible
    for id in 0..cores as u8 {
        p.push(0); // type 0: processor local APIC
        p.push(8); // length
        p.push(id); // ACPI processor uid
        p.push(id); // APIC id
        p.extend_from_slice(&1u32.to_le_bytes()); // enabled
    }
    sdt(b"APIC", 5, &p)
}

/// MCFG: one ECAM allocation (base, segment 0, bus range).
pub fn mcfg(ecam_base: u64, start_bus: u8, end_bus: u8) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&[0u8; 8]); // reserved
    p.extend_from_slice(&ecam_base.to_le_bytes());
    p.extend_from_slice(&0u16.to_le_bytes()); // segment
    p.push(start_bus);
    p.push(end_bus);
    p.extend_from_slice(&[0u8; 4]); // reserved
    sdt(b"MCFG", 1, &p)
}

/// SRAT memory-affinity flags.
pub const SRAT_MEM_ENABLED: u32 = 1 << 0;
pub const SRAT_MEM_HOTPLUG: u32 = 1 << 1;

pub struct SratMem {
    pub domain: u32,
    pub base: u64,
    pub length: u64,
    pub flags: u32,
}

/// SRAT: processor entries (all in domain 0) + memory ranges.
pub fn srat(cores: usize, mems: &[SratMem]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&1u32.to_le_bytes()); // reserved (must be 1)
    p.extend_from_slice(&[0u8; 8]);
    for id in 0..cores as u8 {
        p.push(0); // type 0: processor local APIC affinity
        p.push(16);
        p.push(0); // proximity domain [7:0] = 0
        p.push(id); // APIC id
        p.extend_from_slice(&1u32.to_le_bytes()); // enabled
        p.extend_from_slice(&[0u8; 8]);
    }
    for m in mems {
        p.push(1); // type 1: memory affinity
        p.push(40);
        p.extend_from_slice(&m.domain.to_le_bytes());
        p.extend_from_slice(&[0u8; 2]); // reserved
        p.extend_from_slice(&m.base.to_le_bytes());
        p.extend_from_slice(&m.length.to_le_bytes());
        p.extend_from_slice(&[0u8; 4]); // reserved
        p.extend_from_slice(&m.flags.to_le_bytes());
        p.extend_from_slice(&[0u8; 8]); // reserved
    }
    sdt(b"SRAT", 3, &p)
}

/// CEDT — CXL Early Discovery Table (CXL 2.0 §9.14.1).
pub struct Chbs {
    pub uid: u32,
    /// 0 = CXL 1.1, 1 = CXL 2.0 (register block is component regs).
    pub cxl_version: u32,
    pub base: u64,
    pub length: u64,
}

pub struct Cfmws {
    pub base_hpa: u64,
    pub window_size: u64,
    /// Host-bridge UIDs participating (SLD: one entry; an N-way
    /// interleave set lists its N bridges in slot order).
    pub targets: Vec<u32>,
    /// HBIG: interleave granularity encoding (0 = 256 B, log2(G) - 8).
    pub granularity: u16,
    /// Interleave arithmetic: 0 = modulo, 1 = XOR.
    pub arith: u8,
    /// Restrictions bitfield: bit2 = volatile, bit3 = persistent.
    pub restrictions: u16,
    pub qtg_id: u16,
}

pub fn cedt(chbs: &[Chbs], cfmws: &[Cfmws]) -> Vec<u8> {
    let mut p = Vec::new();
    for c in chbs {
        p.push(0); // structure type 0: CHBS
        p.push(0); // reserved
        p.extend_from_slice(&32u16.to_le_bytes()); // record length
        p.extend_from_slice(&c.uid.to_le_bytes());
        p.extend_from_slice(&c.cxl_version.to_le_bytes());
        p.extend_from_slice(&[0u8; 4]); // reserved
        p.extend_from_slice(&c.base.to_le_bytes());
        p.extend_from_slice(&c.length.to_le_bytes());
    }
    for w in cfmws {
        let niw = w.targets.len();
        assert!(niw.is_power_of_two() && niw <= 16);
        let len = 36 + 4 * niw;
        p.push(1); // structure type 1: CFMWS
        p.push(0);
        p.extend_from_slice(&(len as u16).to_le_bytes());
        p.extend_from_slice(&[0u8; 4]); // reserved
        p.extend_from_slice(&w.base_hpa.to_le_bytes());
        p.extend_from_slice(&w.window_size.to_le_bytes());
        p.push((niw as f64).log2() as u8); // ENIW encoding
        p.push(w.arith); // interleave arithmetic: 0 modulo, 1 XOR
        p.extend_from_slice(&[0u8; 2]);
        p.extend_from_slice(&(w.granularity as u32).to_le_bytes());
        p.extend_from_slice(&w.restrictions.to_le_bytes());
        p.extend_from_slice(&w.qtg_id.to_le_bytes());
        for t in &w.targets {
            p.extend_from_slice(&t.to_le_bytes());
        }
    }
    sdt(b"CEDT", 1, &p)
}

/// HMAT — Heterogeneous Memory Attribute Table (ACPI 6.4 §5.2.27).
/// One latency + one bandwidth "System Locality Latency and Bandwidth
/// Information" structure (type 1), initiator domain 0 against every
/// memory domain — what Linux's memory-tiering policy consumes.
pub struct HmatEntry {
    pub target_domain: u32,
    pub read_lat_ns: f64,
    pub bw_gbps: f64,
}

/// Entry base units: latency in 100 ps, bandwidth in 100 MB/s. The
/// u16 entries then cover 6.5 us and 6.5 TB/s — comfortably above any
/// aggregate interleave-set bandwidth — without saturating.
const HMAT_LAT_BASE_PS: u64 = 100;
const HMAT_BW_BASE_MBPS: u64 = 100;

fn hmat_sllbi(
    data_type: u8,
    base_unit: u64,
    entries: &[HmatEntry],
    value: impl Fn(&HmatEntry) -> u16,
) -> Vec<u8> {
    let t = entries.len();
    // type(2) res(2) len(4) flags(1) dtype(1) minxfer(1) res(1)
    // n_init(4) n_tgt(4) res(4) base_unit(8) + 4*1 + 4*t + 2*1*t
    let len = 32 + 4 + 4 * t + 2 * t;
    let mut s = Vec::with_capacity(len);
    s.extend_from_slice(&1u16.to_le_bytes()); // type 1
    s.extend_from_slice(&[0u8; 2]);
    s.extend_from_slice(&(len as u32).to_le_bytes());
    s.push(0); // flags: memory
    s.push(data_type); // 0 = access latency, 3 = access bandwidth
    s.push(0); // min transfer size
    s.push(0);
    s.extend_from_slice(&1u32.to_le_bytes()); // one initiator (domain 0)
    s.extend_from_slice(&(t as u32).to_le_bytes());
    s.extend_from_slice(&[0u8; 4]);
    s.extend_from_slice(&base_unit.to_le_bytes());
    s.extend_from_slice(&0u32.to_le_bytes()); // initiator domain list
    for e in entries {
        s.extend_from_slice(&e.target_domain.to_le_bytes());
    }
    for e in entries {
        s.extend_from_slice(&value(e).to_le_bytes());
    }
    debug_assert_eq!(s.len(), len);
    s
}

pub fn hmat(entries: &[HmatEntry]) -> Vec<u8> {
    let mut p = Vec::new();
    p.extend_from_slice(&[0u8; 4]); // reserved
    p.extend(hmat_sllbi(0, HMAT_LAT_BASE_PS, entries, |e| {
        ((e.read_lat_ns * 1000.0 / HMAT_LAT_BASE_PS as f64).round()
            as u64)
            .min(u16::MAX as u64) as u16
    }));
    p.extend(hmat_sllbi(3, HMAT_BW_BASE_MBPS, entries, |e| {
        ((e.bw_gbps * 1000.0 / HMAT_BW_BASE_MBPS as f64).round() as u64)
            .min(u16::MAX as u64) as u16
    }));
    sdt(b"HMAT", 2, &p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sdt_checksums_to_zero() {
        let t = sdt(b"TEST", 1, &[1, 2, 3, 4, 5]);
        assert!(table_checksum_ok(&t));
        assert_eq!(&t[0..4], b"TEST");
        assert_eq!(u32::from_le_bytes(t[4..8].try_into().unwrap()), 41);
    }

    #[test]
    fn rsdp_checksums() {
        let r = rsdp(0x1234_5678_9ABC);
        assert_eq!(&r[0..8], b"RSD PTR ");
        assert!(r[..20].iter().fold(0u8, |a, b| a.wrapping_add(*b)) == 0);
        assert!(table_checksum_ok(&r));
        assert_eq!(
            u64::from_le_bytes(r[24..32].try_into().unwrap()),
            0x1234_5678_9ABC
        );
    }

    #[test]
    fn xsdt_entries_roundtrip() {
        let t = xsdt(&[0x1000, 0x2000, 0x3000]);
        assert!(table_checksum_ok(&t));
        let n = (t.len() - 36) / 8;
        assert_eq!(n, 3);
        let e1 = u64::from_le_bytes(t[44..52].try_into().unwrap());
        assert_eq!(e1, 0x2000);
    }

    #[test]
    fn fadt_carries_dsdt_pointers() {
        let t = fadt(0xABCD_0000);
        assert!(table_checksum_ok(&t));
        assert_eq!(
            u32::from_le_bytes(t[36..40].try_into().unwrap()),
            0xABCD_0000
        );
        assert_eq!(
            u64::from_le_bytes(t[140..148].try_into().unwrap()),
            0xABCD_0000
        );
        assert_eq!(t.len(), 276);
    }

    #[test]
    fn madt_one_entry_per_core() {
        let t = madt(4);
        assert!(table_checksum_ok(&t));
        assert_eq!((t.len() - 36 - 8) / 8, 4);
    }

    #[test]
    fn srat_memory_entries() {
        let t = srat(
            2,
            &[
                SratMem { domain: 0, base: 0, length: 2 << 30, flags: SRAT_MEM_ENABLED },
                SratMem {
                    domain: 1,
                    base: 4 << 30,
                    length: 4 << 30,
                    flags: SRAT_MEM_ENABLED | SRAT_MEM_HOTPLUG,
                },
            ],
        );
        assert!(table_checksum_ok(&t));
        // 2 cpu entries (16B) + 2 mem entries (40B) + 12B static.
        assert_eq!(t.len(), 36 + 12 + 32 + 80);
    }

    #[test]
    fn cedt_chbs_cfmws_layout() {
        let t = cedt(
            &[Chbs { uid: 7, cxl_version: 1, base: 0xFE00_0000, length: 0x10000 }],
            &[Cfmws {
                base_hpa: 4 << 30,
                window_size: 4 << 30,
                targets: vec![7],
                granularity: 0,
                arith: 0,
                restrictions: 1 << 2,
                qtg_id: 0,
            }],
        );
        assert!(table_checksum_ok(&t));
        assert_eq!(&t[0..4], b"CEDT");
        // CHBS at 36: type 0, len 32.
        assert_eq!(t[36], 0);
        assert_eq!(u16::from_le_bytes(t[38..40].try_into().unwrap()), 32);
        // CFMWS record follows.
        assert_eq!(t[68], 1);
        let base =
            u64::from_le_bytes(t[68 + 8..68 + 16].try_into().unwrap());
        assert_eq!(base, 4 << 30);
    }

    #[test]
    fn cedt_multiway_cfmws_lists_all_targets() {
        let t = cedt(
            &[],
            &[Cfmws {
                base_hpa: 4 << 30,
                window_size: 8 << 30,
                targets: vec![7, 8, 9, 10],
                granularity: 2, // 1 KiB
                arith: 1,
                restrictions: 1 << 2,
                qtg_id: 0,
            }],
        );
        assert!(table_checksum_ok(&t));
        // CFMWS at 36: ENIW = log2(4) = 2, arith = XOR.
        assert_eq!(t[36], 1);
        assert_eq!(t[36 + 24], 2);
        assert_eq!(t[36 + 25], 1);
        let rec_len =
            u16::from_le_bytes(t[38..40].try_into().unwrap()) as usize;
        assert_eq!(rec_len, 36 + 4 * 4);
        let tgt1 = u32::from_le_bytes(
            t[36 + 36 + 4..36 + 36 + 8].try_into().unwrap(),
        );
        assert_eq!(tgt1, 8);
    }

    #[test]
    fn hmat_structures_checksum_and_count() {
        let t = hmat(&[
            HmatEntry { target_domain: 0, read_lat_ns: 90.0, bw_gbps: 25.6 },
            HmatEntry {
                target_domain: 1,
                read_lat_ns: 250.0,
                bw_gbps: 19.2,
            },
        ]);
        assert!(table_checksum_ok(&t));
        assert_eq!(&t[0..4], b"HMAT");
        // Two type-1 structures after header + 4 reserved bytes.
        let s1 = 36 + 4;
        assert_eq!(u16::from_le_bytes(t[s1..s1 + 2].try_into().unwrap()), 1);
        let l1 =
            u32::from_le_bytes(t[s1 + 4..s1 + 8].try_into().unwrap())
                as usize;
        let s2 = s1 + l1;
        assert_eq!(u16::from_le_bytes(t[s2..s2 + 2].try_into().unwrap()), 1);
        assert_eq!(t[s1 + 9], 0, "first struct carries access latency");
        assert_eq!(t[s2 + 9], 3, "second struct carries bandwidth");
        // Latency entry for domain 1: 250 ns / 100 ps = 2500.
        let entries1 = s1 + 32 + 4 + 8;
        let v = u16::from_le_bytes(
            t[entries1 + 2..entries1 + 4].try_into().unwrap(),
        );
        assert_eq!(v, 2500);
    }
}
