//! E820 physical memory map (the BIOS's first table, paper Fig. 2).

/// E820 entry types (int 15h/AX=E820h ABI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum E820Type {
    Usable = 1,
    Reserved = 2,
    AcpiReclaim = 3,
    AcpiNvs = 4,
}

#[derive(Clone, Copy, Debug)]
pub struct E820Entry {
    pub base: u64,
    pub length: u64,
    pub kind: E820Type,
}

#[derive(Clone, Debug, Default)]
pub struct E820Map {
    pub entries: Vec<E820Entry>,
}

impl E820Map {
    pub fn add(&mut self, base: u64, length: u64, kind: E820Type) {
        assert!(length > 0);
        self.entries.push(E820Entry { base, length, kind });
        self.entries.sort_by_key(|e| e.base);
        // Overlap detection: BIOS bug if ranges collide.
        for w in self.entries.windows(2) {
            assert!(
                w[0].base + w[0].length <= w[1].base,
                "overlapping e820 entries"
            );
        }
    }

    /// Serialize in the 20-byte-per-entry boot-protocol format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.entries.len() * 20);
        for e in &self.entries {
            out.extend_from_slice(&e.base.to_le_bytes());
            out.extend_from_slice(&e.length.to_le_bytes());
            out.extend_from_slice(&(e.kind as u32).to_le_bytes());
        }
        out
    }

    pub fn parse(b: &[u8]) -> Self {
        let mut m = E820Map::default();
        for c in b.chunks_exact(20) {
            let base = u64::from_le_bytes(c[0..8].try_into().unwrap());
            let length = u64::from_le_bytes(c[8..16].try_into().unwrap());
            let kind = match u32::from_le_bytes(c[16..20].try_into().unwrap())
            {
                1 => E820Type::Usable,
                3 => E820Type::AcpiReclaim,
                4 => E820Type::AcpiNvs,
                _ => E820Type::Reserved,
            };
            m.entries.push(E820Entry { base, length, kind });
        }
        m
    }

    pub fn total_usable(&self) -> u64 {
        self.entries
            .iter()
            .filter(|e| e.kind == E820Type::Usable)
            .map(|e| e.length)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = E820Map::default();
        m.add(0, 640 << 10, E820Type::Usable);
        m.add(0xE0000, 128 << 10, E820Type::AcpiReclaim);
        m.add(1 << 20, 2 << 30, E820Type::Usable);
        let b = m.to_bytes();
        let p = E820Map::parse(&b);
        assert_eq!(p.entries.len(), 3);
        assert_eq!(p.total_usable(), (640 << 10) + (2 << 30));
        assert_eq!(p.entries[1].kind, E820Type::AcpiReclaim);
    }

    #[test]
    #[should_panic(expected = "overlapping")]
    fn overlaps_detected() {
        let mut m = E820Map::default();
        m.add(0, 4096, E820Type::Usable);
        m.add(2048, 4096, E820Type::Usable);
    }
}
