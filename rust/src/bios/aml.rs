//! Mini-AML: encoder for the DSDT bytecode the BIOS emits and the
//! interpreter subset the guest uses to walk it.
//!
//! The paper extends gem5's x86 BIOS with an "ACPI ML Interpreter" so
//! the guest can parse dynamic tables (DSDT) that describe compute and
//! memory heterogeneity. We implement the same idea end-to-end with a
//! *real byte-code*: the encoder emits spec-conformant AML opcodes
//! (DefScope 0x10, DefDevice 0x5B 0x82, DefName 0x08, String/DWord/
//! Buffer data, PkgLength encoding per ACPI §20.2), and the guest-side
//! interpreter in `guestos::acpi_parse` decodes them with no shared
//! state. Supported subset: Scope / Device / Name with String, DWord,
//! and Buffer (resource-template) data — enough to describe the CXL
//! host bridge (`ACPI0016`), root-port windows and the MMIO windows for
//! BAR assignment.

/// ---- encoding --------------------------------------------------------

/// Encode a PkgLength prefix (ACPI 6.5 §20.2.4) for `len` bytes of
/// following content. Returns the prefix bytes; total package length
/// includes the prefix itself, which is why encoding iterates.
pub fn pkg_length(content_len: usize) -> Vec<u8> {
    // Total = prefix_len + content_len must fit the encoding.
    for prefix_len in 1..=4usize {
        let total = prefix_len + content_len;
        match prefix_len {
            1 if total <= 0x3F => return vec![total as u8],
            1 => continue,
            n => {
                let bits = (n - 1) * 8 + 4;
                if total < (1usize << bits) {
                    let mut v = Vec::with_capacity(n);
                    v.push((((n - 1) as u8) << 6) | ((total & 0xF) as u8));
                    let mut rest = total >> 4;
                    for _ in 0..n - 1 {
                        v.push((rest & 0xFF) as u8);
                        rest >>= 8;
                    }
                    return v;
                }
            }
        }
    }
    panic!("package too large for AML PkgLength");
}

/// Decode a PkgLength; returns (total_len, prefix_bytes).
pub fn parse_pkg_length(b: &[u8]) -> (usize, usize) {
    let lead = b[0];
    let extra = (lead >> 6) as usize;
    if extra == 0 {
        ((lead & 0x3F) as usize, 1)
    } else {
        let mut total = (lead & 0xF) as usize;
        for i in 0..extra {
            total |= (b[1 + i] as usize) << (4 + 8 * i);
        }
        (total, 1 + extra)
    }
}

/// A 4-char ACPI name segment, space-padded.
pub fn nameseg(name: &str) -> [u8; 4] {
    let mut s = [b'_'; 4];
    for (i, c) in name.bytes().take(4).enumerate() {
        s[i] = c.to_ascii_uppercase();
    }
    s
}

/// EISA ID compression for _HID values like "PNP0A08" / "ACPI0016"
/// (7-char form c1c2c3 + 4 hex digits).
pub fn eisa_id(id: &str) -> u32 {
    let b = id.as_bytes();
    assert_eq!(b.len(), 7, "EISA id must be 7 chars");
    let c = |x: u8| (x - 0x40) as u32 & 0x1F;
    let h = |x: u8| (x as char).to_digit(16).unwrap();
    let sw = (c(b[0]) << 26)
        | (c(b[1]) << 21)
        | (c(b[2]) << 16)
        | (h(b[3]) << 12)
        | (h(b[4]) << 8)
        | (h(b[5]) << 4)
        | h(b[6]);
    sw.swap_bytes()
}

/// AML data values we emit/interpret.
#[derive(Clone, Debug, PartialEq)]
pub enum AmlData {
    Str(String),
    DWord(u32),
    QWord(u64),
    Buffer(Vec<u8>),
}

/// Namespace object builder.
pub enum AmlObj {
    Scope(String, Vec<AmlObj>),
    Device(String, Vec<AmlObj>),
    Name(String, AmlData),
}

pub fn encode(objs: &[AmlObj]) -> Vec<u8> {
    let mut out = Vec::new();
    for o in objs {
        encode_obj(o, &mut out);
    }
    out
}

fn encode_obj(o: &AmlObj, out: &mut Vec<u8>) {
    match o {
        AmlObj::Scope(name, kids) => {
            let mut body = Vec::new();
            body.extend_from_slice(&encode_namestring(name));
            for k in kids {
                encode_obj(k, &mut body);
            }
            out.push(0x10); // ScopeOp
            out.extend_from_slice(&pkg_length(body.len()));
            out.extend_from_slice(&body);
        }
        AmlObj::Device(name, kids) => {
            let mut body = Vec::new();
            body.extend_from_slice(&encode_namestring(name));
            for k in kids {
                encode_obj(k, &mut body);
            }
            out.push(0x5B); // ExtOpPrefix
            out.push(0x82); // DeviceOp
            out.extend_from_slice(&pkg_length(body.len()));
            out.extend_from_slice(&body);
        }
        AmlObj::Name(name, data) => {
            out.push(0x08); // NameOp
            out.extend_from_slice(&encode_namestring(name));
            encode_data(data, out);
        }
    }
}

fn encode_namestring(name: &str) -> Vec<u8> {
    // Support "\\_SB" rooted and plain single segments.
    let mut out = Vec::new();
    let n = if let Some(rest) = name.strip_prefix('\\') {
        out.push(b'\\');
        rest
    } else {
        name
    };
    let segs: Vec<&str> = n.split('.').collect();
    match segs.len() {
        1 => out.extend_from_slice(&nameseg(segs[0])),
        2 => {
            out.push(0x2E); // DualNamePrefix
            out.extend_from_slice(&nameseg(segs[0]));
            out.extend_from_slice(&nameseg(segs[1]));
        }
        _ => panic!("multi-segment paths beyond 2 unsupported"),
    }
    out
}

fn encode_data(d: &AmlData, out: &mut Vec<u8>) {
    match d {
        AmlData::Str(s) => {
            out.push(0x0D);
            out.extend_from_slice(s.as_bytes());
            out.push(0);
        }
        AmlData::DWord(v) => {
            out.push(0x0C);
            out.extend_from_slice(&v.to_le_bytes());
        }
        AmlData::QWord(v) => {
            out.push(0x0E);
            out.extend_from_slice(&v.to_le_bytes());
        }
        AmlData::Buffer(b) => {
            // BufferOp PkgLength BufferSize(DWordConst) bytes
            let mut size = Vec::new();
            size.push(0x0C);
            size.extend_from_slice(&(b.len() as u32).to_le_bytes());
            let content_len = size.len() + b.len();
            out.push(0x11);
            out.extend_from_slice(&pkg_length(content_len));
            out.extend_from_slice(&size);
            out.extend_from_slice(b);
        }
    }
}

/// ---- resource templates ------------------------------------------------

/// QWordMemory descriptor (ACPI §6.4.3.5.1) for a _CRS buffer.
pub fn qword_memory(min: u64, len: u64) -> Vec<u8> {
    let mut d = Vec::with_capacity(0x2E);
    d.push(0x8A); // QWORD address space descriptor
    d.extend_from_slice(&0x2Bu16.to_le_bytes()); // length
    d.push(0); // resource type: memory
    d.push(0x0C); // general flags: min/max fixed... (producer)
    d.push(0x01); // type-specific: read/write
    d.extend_from_slice(&0u64.to_le_bytes()); // granularity
    d.extend_from_slice(&min.to_le_bytes()); // range minimum
    d.extend_from_slice(&(min + len - 1).to_le_bytes()); // range maximum
    d.extend_from_slice(&0u64.to_le_bytes()); // translation
    d.extend_from_slice(&len.to_le_bytes()); // length
    d
}

/// End tag closing a resource template.
pub fn end_tag() -> Vec<u8> {
    vec![0x79, 0x00]
}

/// Parse all QWordMemory windows out of a _CRS buffer.
pub fn parse_crs_memory(buf: &[u8]) -> Vec<(u64, u64)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < buf.len() {
        let b = buf[i];
        if b == 0x79 {
            break; // end tag
        }
        if b & 0x80 != 0 {
            // Large descriptor.
            let len =
                u16::from_le_bytes([buf[i + 1], buf[i + 2]]) as usize;
            if b == 0x8A && len >= 0x2B {
                let g = |o: usize| {
                    u64::from_le_bytes(
                        buf[i + o..i + o + 8].try_into().unwrap(),
                    )
                };
                let min = g(6 + 8);
                let l = g(6 + 32);
                out.push((min, l));
            }
            i += 3 + len;
        } else {
            // Small descriptor: low 3 bits = length.
            i += 1 + (b & 0x7) as usize;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pkg_length_roundtrip() {
        for content in [0usize, 1, 0x3D, 0x3E, 0x100, 0xFFF, 0x10000] {
            let p = pkg_length(content);
            let (total, plen) = parse_pkg_length(&p);
            assert_eq!(plen, p.len());
            assert_eq!(total, content + plen, "content={content}");
        }
    }

    #[test]
    fn eisa_id_known_values() {
        // PNP0A08 == 0x080AD041 (little-endian dword in AML).
        assert_eq!(eisa_id("PNP0A08"), 0x41D00A08u32.swap_bytes().swap_bytes().to_le().swap_bytes());
        // Sanity: round-trip shape — first byte after swap is compressed 'P','N','P'.
        let v = eisa_id("PNP0A08").to_le_bytes();
        assert_eq!(v[0], 0x41); // "PNP" compresses to 0x41D0
        assert_eq!(v[1], 0xD0);
        assert_eq!(v[2], 0x0A);
        assert_eq!(v[3], 0x08);
    }

    #[test]
    fn nameseg_pads() {
        assert_eq!(&nameseg("CXL0"), b"CXL0");
        assert_eq!(&nameseg("SB"), b"SB__");
    }

    #[test]
    fn qword_memory_parses_back() {
        let mut crs = qword_memory(0xE000_0000, 0x1000_0000);
        crs.extend(qword_memory(4 << 30, 4 << 30));
        crs.extend(end_tag());
        let ws = parse_crs_memory(&crs);
        assert_eq!(
            ws,
            vec![(0xE000_0000, 0x1000_0000), (4 << 30, 4 << 30)]
        );
    }

    #[test]
    fn encode_emits_expected_opcodes() {
        let aml = encode(&[AmlObj::Scope(
            "\\_SB".into(),
            vec![AmlObj::Device(
                "PC00".into(),
                vec![AmlObj::Name(
                    "_HID".into(),
                    AmlData::DWord(eisa_id("PNP0A08")),
                )],
            )],
        )]);
        assert_eq!(aml[0], 0x10); // ScopeOp
        assert!(aml.windows(2).any(|w| w == [0x5B, 0x82])); // DeviceOp
        assert!(aml.contains(&0x08)); // NameOp
    }
}
