//! The x86 BIOS model (paper Fig. 2 — "Modeled X86 Bios in gem5 to
//! support CXL2.0 devices").
//!
//! Assembles, as real bytes in simulated physical memory:
//!   * the E820 physical memory map,
//!   * RSDP -> XSDT -> { FADT(-> DSDT), MADT, MCFG, SRAT, CEDT },
//!   * the DSDT's AML byte-code describing the PCIe host bridge
//!     (PNP0A08) with its ECAM + MMIO windows and the CXL host bridge
//!     (ACPI0016) with its component-register block.
//!
//! The guest OS model ([`crate::guestos`]) discovers everything by
//! parsing these bytes — the BIOS and the guest share only the RSDP
//! scan region, exactly like real firmware and kernel.

pub mod acpi;
pub mod aml;
pub mod e820;

use crate::config::SimConfig;
use crate::mem::PhysMem;

use acpi::{Cfmws, Chbs, HmatEntry, SratMem};
use aml::{AmlData, AmlObj};
use e820::{E820Map, E820Type};

use crate::config::InterleaveArith;

/// Fixed platform addresses (the "motherboard wiring").
pub mod layout {
    /// RSDP lives in the classic BIOS search window.
    pub const RSDP_ADDR: u64 = 0xE_0000;
    /// ACPI tables are packed upward from here.
    pub const ACPI_POOL: u64 = 0xE_1000;
    /// E820 map bytes (as the bootloader would pass them).
    pub const E820_ADDR: u64 = 0x9_0000;
    /// ECAM window (16 buses x 1 MiB — besides bus 0, switched
    /// topologies burn two buses per switch (upstream-bridge bus +
    /// internal bus) plus one leaf bus per endpoint).
    pub const ECAM_BASE: u64 = 0xE000_0000;
    pub const ECAM_BUSES: u8 = 16;
    /// MMIO window for BAR assignment.
    pub const MMIO_BASE: u64 = 0xF000_0000;
    pub const MMIO_SIZE: u64 = 0x0800_0000;
    /// CXL host-bridge component register blocks (CHBS targets): one
    /// block of `CHBS_SIZE` per host bridge, packed from `CHBS_BASE`.
    pub const CHBS_BASE: u64 = 0xF000_0000;
    pub const CHBS_SIZE: u64 = 0x1_0000;
    /// First CXL host bridge ACPI UID (bridge `i` gets `CHB_UID + i`).
    pub const CHB_UID: u32 = 7;

    /// CHBS block base for host bridge `i`.
    pub fn chbs_base(i: usize) -> u64 {
        CHBS_BASE + (i as u64) * CHBS_SIZE
    }
}

/// Everything the BIOS decided, for the machine builder's benefit
/// (the guest does NOT get this struct — it parses memory).
#[derive(Clone, Debug)]
pub struct BiosInfo {
    pub rsdp_addr: u64,
    pub e820_addr: u64,
    pub e820_len: usize,
    pub ecam_base: u64,
    /// Base of the first CXL fixed window (span start).
    pub cxl_window_base: u64,
    /// Span from the first window's base to the last window's end
    /// (may include alignment gaps between windows).
    pub cxl_window_size: u64,
    /// One `(base, size)` per published window, in window order.
    pub cxl_windows: Vec<(u64, u64)>,
    /// For each published window, the index of its definition in
    /// `cfg.cxl.window_defs()` — the identity the machine needs to
    /// mirror routing windows when a host publishes only the subset of
    /// windows the fabric manager bound to it.
    pub cxl_window_defs: Vec<usize>,
    /// First 1 GiB-aligned address after the last published window —
    /// the next host's BIOS starts here so fabric-wide host physical
    /// window bases stay globally unique (what keeps a shared MLD's
    /// per-LD decoders unambiguous across hosts).
    pub next_free_base: u64,
    pub tables_end: u64,
}

/// Place the CXL fixed memory window: above both 4 GiB and system DRAM,
/// 1 GiB-aligned.
pub fn cxl_window_base(sys_mem_size: u64) -> u64 {
    let align = 1u64 << 30;
    let min = 1u64 << 32;
    let top = sys_mem_size.max(min);
    top.div_ceil(align) * align
}

/// Build the BIOS into `mem` per `cfg`, publishing every CXL window
/// (the single-host view). Returns the placement info.
pub fn build(cfg: &SimConfig, mem: &mut PhysMem) -> BiosInfo {
    let all: Vec<usize> = (0..cfg.cxl.window_defs().len()).collect();
    build_with(cfg, mem, &all, cxl_window_base(cfg.sys_mem_size))
}

/// Build the BIOS into `mem`, publishing only the window definitions in
/// `def_indices` (indices into `cfg.cxl.window_defs()`), with the first
/// window placed at `first_base` (clamped above system DRAM / 4 GiB).
/// This is the multi-host entry point: host N's firmware describes only
/// the logical devices the fabric manager bound to it, at host physical
/// bases disjoint from every other host's.
pub fn build_with(
    cfg: &SimConfig,
    mem: &mut PhysMem,
    def_indices: &[usize],
    first_base: u64,
) -> BiosInfo {
    let n_bridges = cfg.cxl.bridges();
    let all_defs = cfg.cxl.window_defs();
    let window_defs: Vec<&crate::config::CxlWindowDef> =
        def_indices.iter().map(|&i| &all_defs[i]).collect();

    // One fixed window per published definition (interleave set or MLD
    // logical-device slice), 1 GiB-aligned, packed upward.
    let mut windows = Vec::with_capacity(window_defs.len());
    let mut next_base = first_base.max(cxl_window_base(cfg.sys_mem_size));
    for def in &window_defs {
        windows.push((next_base, def.size));
        next_base = (next_base + def.size).div_ceil(1 << 30) * (1 << 30);
    }
    let span_base = windows.first().map(|w| w.0).unwrap_or(next_base);
    let span_size = match windows.last() {
        Some(&(last_base, last_size)) => last_base + last_size - span_base,
        None => 0,
    };

    // ---- E820 -----------------------------------------------------------
    let mut e820 = E820Map::default();
    e820.add(0, 640 << 10, E820Type::Usable);
    e820.add(layout::RSDP_ADDR, 128 << 10, E820Type::AcpiReclaim);
    e820.add(1 << 20, cfg.sys_mem_size - (1 << 20), E820Type::Usable);
    // The CXL window is NOT in E820 — it appears via CEDT/SRAT and is
    // hot-added by the driver; that asymmetry is the zNUMA mechanism.
    let e820_bytes = e820.to_bytes();
    mem.write(layout::E820_ADDR, &e820_bytes);

    // ---- DSDT (AML) -------------------------------------------------------
    let mut sb_devices = vec![AmlObj::Device(
        "PC00".into(),
        vec![
            AmlObj::Name(
                "_HID".into(),
                AmlData::DWord(aml::eisa_id("PNP0A08")),
            ),
            AmlObj::Name("_UID".into(), AmlData::DWord(0)),
            AmlObj::Name("_CRS".into(), AmlData::Buffer({
                let mut b = aml::qword_memory(
                    layout::ECAM_BASE,
                    (layout::ECAM_BUSES as u64) << 20,
                );
                b.extend(aml::qword_memory(
                    layout::MMIO_BASE,
                    layout::MMIO_SIZE,
                ));
                b.extend(aml::end_tag());
                b
            })),
        ],
    )];
    for i in 0..n_bridges {
        // ACPI0016 — CXL host bridge (what linux's cxl_acpi binds to);
        // one per root port — per switch when switches are configured,
        // else per expander card — each with its own CHBS block.
        sb_devices.push(AmlObj::Device(
            format!("CXL{i}"),
            vec![
                AmlObj::Name("_HID".into(), AmlData::Str("ACPI0016".into())),
                AmlObj::Name(
                    "_CID".into(),
                    AmlData::DWord(aml::eisa_id("PNP0A08")),
                ),
                AmlObj::Name(
                    "_UID".into(),
                    AmlData::DWord(layout::CHB_UID + i as u32),
                ),
                AmlObj::Name("_CRS".into(), AmlData::Buffer({
                    let mut b = aml::qword_memory(
                        layout::chbs_base(i),
                        layout::CHBS_SIZE,
                    );
                    b.extend(aml::end_tag());
                    b
                })),
            ],
        ));
    }
    let dsdt_aml =
        aml::encode(&[AmlObj::Scope("\\_SB".into(), sb_devices)]);
    let dsdt = acpi::sdt(b"DSDT", 2, &dsdt_aml);

    // ---- fixed tables ------------------------------------------------------
    let madt = acpi::madt(cfg.cores);
    let mcfg = acpi::mcfg(layout::ECAM_BASE, 0, layout::ECAM_BUSES - 1);
    let mut srat_mems = vec![SratMem {
        domain: 0,
        base: 0,
        length: cfg.sys_mem_size,
        flags: acpi::SRAT_MEM_ENABLED,
    }];
    for (w, &(base, size)) in windows.iter().enumerate() {
        // One zNUMA (CPU-less) domain per window: enabled + hot-
        // pluggable, no processor affinity entries reference it.
        srat_mems.push(SratMem {
            domain: 1 + w as u32,
            base,
            length: size,
            flags: acpi::SRAT_MEM_ENABLED | acpi::SRAT_MEM_HOTPLUG,
        });
    }
    let srat = acpi::srat(cfg.cores, &srat_mems);

    let chbs: Vec<Chbs> = (0..n_bridges)
        .map(|i| Chbs {
            uid: layout::CHB_UID + i as u32,
            cxl_version: 1, // CXL 2.0: block is component registers
            base: layout::chbs_base(i),
            length: layout::CHBS_SIZE,
        })
        .collect();
    let hbig =
        (cfg.cxl.interleave_granularity.trailing_zeros() - 8) as u16;
    let arith = match cfg.cxl.interleave_arith {
        InterleaveArith::Modulo => 0u8,
        InterleaveArith::Xor => 1,
    };
    let cfmws: Vec<Cfmws> = windows
        .iter()
        .zip(&window_defs)
        .map(|(&(base, size), def)| Cfmws {
            base_hpa: base,
            window_size: size,
            // Targets are host-bridge UIDs: the bridge owning each
            // member device (per-LD windows of one MLD all target the
            // same bridge, in consecutive CFMWS records).
            targets: def
                .targets
                .iter()
                .map(|&i| layout::CHB_UID + cfg.cxl.bridge_of(i) as u32)
                .collect(),
            granularity: hbig,
            arith,
            restrictions: 1 << 2, // volatile
            qtg_id: 0,
        })
        .collect();
    let cedt = acpi::cedt(&chbs, &cfmws);

    // HMAT: access latency/bandwidth from initiator domain 0 to every
    // memory domain — DRAM from the channel timing, each CXL window
    // from its first member's path (link + switch hops) + media.
    let mut hmat_entries = vec![HmatEntry {
        target_domain: 0,
        read_lat_ns: cfg.sys_dram.t_rcd_ns + cfg.sys_dram.t_cas_ns,
        bw_gbps: cfg.sys_dram.bw_gbps,
    }];
    for (w, def) in window_defs.iter().enumerate() {
        let d0 = cfg.cxl.device(def.targets[0]);
        let bw: f64 = def
            .targets
            .iter()
            .map(|&i| {
                let d = cfg.cxl.device(i);
                let mut b = d.link_bw_gbps.min(d.media.bw_gbps);
                if let Some(j) = cfg.cxl.switch_of(i) {
                    // The shared upstream link caps a switched path.
                    b = b.min(cfg.cxl.switch(j).link_bw_gbps);
                }
                b
            })
            .sum();
        hmat_entries.push(HmatEntry {
            target_domain: 1 + w as u32,
            read_lat_ns: 2.0
                * (cfg.cxl.pkt_lat_ns
                    + cfg.cxl.depkt_lat_ns
                    + cfg.cxl.path_lat_ns(def.targets[0]))
                + d0.media.t_rcd_ns
                + d0.media.t_cas_ns,
            bw_gbps: bw,
        });
    }
    let hmat = acpi::hmat(&hmat_entries);

    // ---- pack tables & pointers -----------------------------------------
    let mut cursor = layout::ACPI_POOL;
    let mut place = |mem: &mut PhysMem, bytes: &[u8]| -> u64 {
        let at = cursor;
        mem.write(at, bytes);
        cursor = (at + bytes.len() as u64 + 63) & !63;
        at
    };
    let dsdt_addr = place(mem, &dsdt);
    let fadt = acpi::fadt(dsdt_addr);
    let fadt_addr = place(mem, &fadt);
    let madt_addr = place(mem, &madt);
    let mcfg_addr = place(mem, &mcfg);
    let srat_addr = place(mem, &srat);
    let cedt_addr = place(mem, &cedt);
    let hmat_addr = place(mem, &hmat);
    let xsdt = acpi::xsdt(&[
        fadt_addr, madt_addr, mcfg_addr, srat_addr, cedt_addr, hmat_addr,
    ]);
    let xsdt_addr = place(mem, &xsdt);
    mem.write(layout::RSDP_ADDR, &acpi::rsdp(xsdt_addr));

    BiosInfo {
        rsdp_addr: layout::RSDP_ADDR,
        e820_addr: layout::E820_ADDR,
        e820_len: e820_bytes.len(),
        ecam_base: layout::ECAM_BASE,
        cxl_window_base: span_base,
        cxl_window_size: span_size,
        cxl_windows: windows,
        cxl_window_defs: def_indices.to_vec(),
        next_free_base: next_base,
        tables_end: cursor,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_placement() {
        assert_eq!(cxl_window_base(2 << 30), 4 << 30);
        assert_eq!(cxl_window_base(8 << 30), 8 << 30);
        assert_eq!(cxl_window_base((8 << 30) + 5), (8 << 30) + (1 << 30));
    }

    #[test]
    fn bios_builds_parseable_tables() {
        let cfg = SimConfig::default();
        let mut mem = PhysMem::new();
        let info = build(&cfg, &mut mem);

        // RSDP signature + checksum.
        let mut rsdp = vec![0u8; 36];
        mem.read(info.rsdp_addr, &mut rsdp);
        assert_eq!(&rsdp[0..8], b"RSD PTR ");
        assert!(acpi::table_checksum_ok(&rsdp));

        // XSDT reachable and valid.
        let xsdt_addr =
            u64::from_le_bytes(rsdp[24..32].try_into().unwrap());
        let len = mem.read_u32(xsdt_addr + 4) as usize;
        let mut x = vec![0u8; len];
        mem.read(xsdt_addr, &mut x);
        assert_eq!(&x[0..4], b"XSDT");
        assert!(acpi::table_checksum_ok(&x));
        assert_eq!((len - 36) / 8, 6); // six tables (incl. HMAT)

        // E820 parses and covers system memory.
        let mut e = vec![0u8; info.e820_len];
        mem.read(info.e820_addr, &mut e);
        let map = e820::E820Map::parse(&e);
        assert!(map.total_usable() > (cfg.sys_mem_size * 9) / 10);
    }

    #[test]
    fn signatures_present_exactly_once() {
        let cfg = SimConfig::default();
        let mut mem = PhysMem::new();
        let info = build(&cfg, &mut mem);
        let mut blob = vec![0u8; (info.tables_end - layout::ACPI_POOL) as usize];
        mem.read(layout::ACPI_POOL, &mut blob);
        for sig in
            [b"FACP", b"APIC", b"MCFG", b"SRAT", b"CEDT", b"DSDT", b"HMAT"]
        {
            let count = blob
                .windows(4)
                .filter(|w| w == sig)
                .count();
            assert_eq!(count, 1, "{}", String::from_utf8_lossy(sig));
        }
    }

    #[test]
    fn switched_bios_publishes_one_bridge_per_switch() {
        let mut cfg = SimConfig::default();
        cfg.cxl.devices = 4;
        cfg.cxl.switches = 1;
        cfg.cxl.mem_size = 512 << 20;
        cfg.validate().unwrap();
        let mut mem = PhysMem::new();
        let info = build(&cfg, &mut mem);
        // One window per device (switched auto = 1-way).
        assert_eq!(info.cxl_windows.len(), 4);
        // The CEDT carries exactly one CHBS (one root port / bridge).
        let parsed = crate::guestos::acpi_parse::parse(
            &mem,
            layout::RSDP_ADDR & !0xFFFF,
        )
        .unwrap();
        assert_eq!(parsed.chbs.len(), 1);
        assert_eq!(parsed.cfmws.len(), 4);
        for w in &parsed.cfmws {
            assert_eq!(w.targets, vec![layout::CHB_UID]);
        }
    }

    #[test]
    fn mld_bios_publishes_per_ld_windows() {
        let mut cfg = SimConfig::default();
        cfg.cxl.interleave_ways = 1;
        cfg.cxl.dev_overrides =
            vec![crate::config::CxlDevOverride {
                lds: Some(2),
                ..Default::default()
            }];
        cfg.validate().unwrap();
        let mut mem = PhysMem::new();
        let info = build(&cfg, &mut mem);
        assert_eq!(info.cxl_windows.len(), 2, "one window per LD");
        assert_eq!(info.cxl_windows[0].1, 2 << 30);
        assert_eq!(info.cxl_windows[1].1, 2 << 30);
        let parsed = crate::guestos::acpi_parse::parse(
            &mem,
            layout::RSDP_ADDR & !0xFFFF,
        )
        .unwrap();
        // Both slice windows target the same (single) host bridge and
        // get their own SRAT domains.
        assert_eq!(parsed.cfmws.len(), 2);
        assert_eq!(parsed.cfmws[0].targets, parsed.cfmws[1].targets);
        assert_eq!(parsed.mem_affinity.len(), 3);
    }

    #[test]
    fn per_host_bios_publishes_subset_at_disjoint_bases() {
        // An MLD with two LDs parceled to two hosts: each host's BIOS
        // publishes one window, and the second host's base continues
        // above the first host's span.
        let mut cfg = SimConfig::default();
        cfg.hosts = 2;
        cfg.cxl.interleave_ways = 1;
        cfg.cxl.dev_overrides = vec![crate::config::CxlDevOverride {
            lds: Some(2),
            ..Default::default()
        }];
        cfg.validate().unwrap();
        let mut mem0 = PhysMem::new();
        let info0 = build_with(
            &cfg,
            &mut mem0,
            &[0],
            cxl_window_base(cfg.sys_mem_size),
        );
        let mut mem1 = PhysMem::new();
        let info1 =
            build_with(&cfg, &mut mem1, &[1], info0.next_free_base);
        assert_eq!(info0.cxl_windows.len(), 1);
        assert_eq!(info1.cxl_windows.len(), 1);
        assert_eq!(info0.cxl_window_defs, vec![0]);
        assert_eq!(info1.cxl_window_defs, vec![1]);
        let (b0, s0) = info0.cxl_windows[0];
        let (b1, _) = info1.cxl_windows[0];
        assert!(b1 >= b0 + s0, "host windows must not overlap");
        // Each host's tables parse and carry exactly one CXL domain.
        for mem in [&mem0, &mem1] {
            let parsed = crate::guestos::acpi_parse::parse(
                mem,
                layout::RSDP_ADDR & !0xFFFF,
            )
            .unwrap();
            assert_eq!(parsed.cfmws.len(), 1);
            assert_eq!(parsed.mem_affinity.len(), 2);
        }
    }

    #[test]
    fn host_without_windows_gets_dram_only_tables() {
        let cfg = SimConfig::default();
        let mut mem = PhysMem::new();
        let info = build_with(
            &cfg,
            &mut mem,
            &[],
            cxl_window_base(cfg.sys_mem_size),
        );
        assert!(info.cxl_windows.is_empty());
        assert_eq!(info.cxl_window_size, 0);
        let parsed = crate::guestos::acpi_parse::parse(
            &mem,
            layout::RSDP_ADDR & !0xFFFF,
        )
        .unwrap();
        assert!(parsed.cfmws.is_empty());
        assert_eq!(parsed.mem_affinity.len(), 1, "DRAM domain only");
    }

    #[test]
    fn multi_device_windows_and_domains() {
        let mut cfg = SimConfig::default();
        cfg.cxl.devices = 4;
        cfg.cxl.interleave_ways = 2; // two sets of two devices
        cfg.cxl.mem_size = 512 << 20;
        let mut mem = PhysMem::new();
        let info = build(&cfg, &mut mem);
        assert_eq!(info.cxl_windows.len(), 2);
        assert_eq!(info.cxl_windows[0].1, 1 << 30, "2 x 512 MiB per set");
        // Windows are disjoint and 1 GiB-aligned.
        let (b0, s0) = info.cxl_windows[0];
        let (b1, _) = info.cxl_windows[1];
        assert!(b1 >= b0 + s0);
        assert_eq!(b1 % (1 << 30), 0);
        assert_eq!(info.cxl_window_base, b0);
    }
}
