//! CPU models (paper Table I: In-order and Out-of-Order).
//!
//! Both models consume a workload's operation stream and differ in how
//! much memory-level parallelism they extract:
//!
//! * **InOrder** ("Timing"-CPU analogue): one outstanding memory
//!   operation; `Work` advances the issue clock; every miss fully
//!   serializes. MLP = 1.
//! * **OutOfOrder** (O3 analogue): up to `issue_width` ops issued per
//!   cycle into an LSQ of `lsq_entries`; memory ops occupy an LSQ slot
//!   until their response returns; the core stalls only when the LSQ
//!   (or ROB occupancy proxy) is exhausted. Retirement is in-order.
//!
//! The microarchitectural simplification (no rename/bypass modeling) is
//! documented in DESIGN.md §S9: what Fig. 5 needs is the contrast in
//! outstanding-miss behaviour between the two models, which this
//! captures; absolute IPC is calibratable via `issue_width`.

use crate::config::{CpuModel, SimConfig};
use crate::sim::{ReqId, Tick};
use crate::stats::{Counter, Histogram, StatDump};

/// One workload operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WlOp {
    /// Load `size` bytes at VA.
    Load { va: u64, size: u32 },
    /// Store `size` bytes at VA.
    Store { va: u64, size: u32 },
    /// Pure compute for `cycles`.
    Work { cycles: u64 },
}

/// A memory op in flight from this core.
#[derive(Clone, Copy, Debug)]
pub struct InFlight {
    pub req: ReqId,
    pub issued_at: Tick,
    pub is_store: bool,
}

#[derive(Clone, Debug, Default)]
pub struct CoreStats {
    pub loads: Counter,
    pub stores: Counter,
    pub work_cycles: Counter,
    pub lsq_full_stalls: Counter,
    pub mem_latency: Histogram,
    pub finished_at: Tick,
}

/// Per-core issue state machine. The system layer drives it:
/// `can_issue` -> pull an op from the workload -> `begin_mem`/`do_work`;
/// responses come back via `complete_mem`.
#[derive(Clone, Debug)]
pub struct Core {
    pub id: u8,
    pub model: CpuModel,
    cycle_ticks: Tick,
    issue_width: usize,
    lsq_cap: usize,
    inflight: Vec<InFlight>,
    /// Next tick at which the front-end may issue (advanced by Work and
    /// by issue-width accounting).
    pub next_issue: Tick,
    /// Ops issued in the current cycle window.
    issued_this_cycle: usize,
    pub done: bool,
    pub stats: CoreStats,
}

impl Core {
    pub fn new(id: u8, cfg: &SimConfig) -> Self {
        let (issue_width, lsq_cap) = match cfg.cpu_model {
            CpuModel::InOrder => (1, 1),
            CpuModel::OutOfOrder => (cfg.issue_width, cfg.lsq_entries),
        };
        Core {
            id,
            model: cfg.cpu_model,
            cycle_ticks: crate::sim::ns_to_ticks(cfg.cycle_ns()).max(1),
            issue_width,
            lsq_cap,
            inflight: Vec::new(),
            next_issue: 0,
            issued_this_cycle: 0,
            done: false,
            stats: CoreStats::default(),
        }
    }

    pub fn lsq_free(&self) -> bool {
        self.inflight.len() < self.lsq_cap
    }

    pub fn outstanding(&self) -> usize {
        self.inflight.len()
    }

    /// Can the front-end issue at `now`?
    pub fn can_issue(&self, now: Tick) -> bool {
        !self.done && now >= self.next_issue && self.lsq_free()
    }

    fn charge_issue_slot(&mut self, now: Tick) {
        self.issued_this_cycle += 1;
        if self.issued_this_cycle >= self.issue_width {
            self.issued_this_cycle = 0;
            self.next_issue = now + self.cycle_ticks;
        }
    }

    /// Record a memory op entering the machine.
    pub fn begin_mem(&mut self, now: Tick, req: ReqId, is_store: bool) {
        debug_assert!(self.lsq_free());
        if is_store {
            self.stats.stores.inc();
        } else {
            self.stats.loads.inc();
        }
        self.inflight.push(InFlight { req, issued_at: now, is_store });
        self.charge_issue_slot(now);
    }

    /// Record pure compute: advances the issue clock.
    pub fn do_work(&mut self, now: Tick, cycles: u64) {
        self.stats.work_cycles.add(cycles);
        self.next_issue =
            self.next_issue.max(now) + cycles * self.cycle_ticks;
        self.issued_this_cycle = 0;
    }

    /// A response arrived; returns the original issue tick.
    pub fn complete_mem(&mut self, now: Tick, req: ReqId) -> Option<Tick> {
        let idx = self.inflight.iter().position(|f| f.req == req)?;
        // Order is irrelevant (lookup is by id): avoid the O(n) shift.
        let f = self.inflight.swap_remove(idx);
        self.stats.mem_latency.sample(now - f.issued_at);
        // In-order core blocks the front-end on the outstanding op.
        if self.model == CpuModel::InOrder {
            self.next_issue = self.next_issue.max(now);
        }
        Some(f.issued_at)
    }

    pub fn note_lsq_stall(&mut self) {
        self.stats.lsq_full_stalls.inc();
    }

    pub fn finish(&mut self, now: Tick) {
        self.done = true;
        self.stats.finished_at = now;
    }

    pub fn dump(&self, path: &str, d: &mut StatDump) {
        d.counter(&format!("{path}.loads"), &self.stats.loads);
        d.counter(&format!("{path}.stores"), &self.stats.stores);
        d.counter(&format!("{path}.work_cycles"), &self.stats.work_cycles);
        d.counter(
            &format!("{path}.lsq_full_stalls"),
            &self.stats.lsq_full_stalls,
        );
        d.hist(&format!("{path}.mem_latency"), &self.stats.mem_latency);
        d.push(&format!("{path}.finished_at"), self.stats.finished_at as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(model: CpuModel) -> SimConfig {
        let mut c = SimConfig::default();
        c.cpu_model = model;
        c
    }

    #[test]
    fn inorder_single_outstanding() {
        let mut c = Core::new(0, &cfg(CpuModel::InOrder));
        assert!(c.can_issue(0));
        c.begin_mem(0, 1, false);
        assert!(!c.lsq_free());
        assert!(!c.can_issue(1000));
        c.complete_mem(5000, 1);
        assert!(c.can_issue(5000));
        assert_eq!(c.stats.mem_latency.stats.mean(), 5000.0);
    }

    #[test]
    fn o3_extracts_mlp() {
        let mut c = Core::new(0, &cfg(CpuModel::OutOfOrder));
        let mut t = 0;
        let mut n = 0;
        // Issue until LSQ fills.
        while c.can_issue(t) {
            c.begin_mem(t, n, false);
            n += 1;
            if c.issued_this_cycle == 0 {
                t = c.next_issue;
            }
        }
        assert_eq!(c.outstanding(), 48); // default lsq_entries
        // 4-wide: 48 ops take 12 cycles of issue.
        assert!(t >= 11 * c.cycle_ticks);
    }

    #[test]
    fn issue_width_paces_front_end() {
        let mut c = Core::new(0, &cfg(CpuModel::OutOfOrder));
        for i in 0..4 {
            assert!(c.can_issue(0), "op {i} should fit in cycle 0");
            c.begin_mem(0, i, false);
        }
        assert!(!c.can_issue(0), "5th op must wait a cycle");
        assert!(c.can_issue(c.next_issue));
    }

    #[test]
    fn work_advances_clock() {
        let mut c = Core::new(0, &cfg(CpuModel::InOrder));
        c.do_work(0, 10);
        assert!(!c.can_issue(0));
        assert!(c.can_issue(10 * c.cycle_ticks));
        assert_eq!(c.stats.work_cycles.get(), 10);
    }

    #[test]
    fn complete_unknown_req_is_none() {
        let mut c = Core::new(0, &cfg(CpuModel::OutOfOrder));
        assert!(c.complete_mem(0, 99).is_none());
    }
}
