//! PJRT runtime bridge: load and execute the AOT-compiled HLO artifacts.
//!
//! Python runs once at build time (`make artifacts`); this module loads
//! `artifacts/*.hlo.txt` through the `xla` crate's PJRT CPU client —
//! `HloModuleProto::from_text_file` -> `XlaComputation` -> `compile` —
//! and exposes typed entry points for the three compute graphs:
//! [`XlaRuntime::cache_warm`], [`XlaRuntime::calib_step`] and
//! [`XlaRuntime::lat_bw_sweep`]. HLO *text* is the interchange format
//! (serialized protos from jax >= 0.5 are rejected by xla_extension
//! 0.5.1 — see python/compile/aot.py).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Parsed artifacts/manifest.json — the geometry contract between the
/// Python AOT pipeline and the Rust caller.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub window: usize,
    pub l1_sets: usize,
    pub l1_ways: usize,
    pub l2_sets: usize,
    pub l2_ways: usize,
    pub calib_points: usize,
    pub sweep_points: usize,
    pub files: Vec<(String, PathBuf)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| {
                format!(
                    "missing {}/manifest.json — run `make artifacts`",
                    dir.display()
                )
            })?;
        let j = Json::parse(&text).context("manifest is not valid JSON")?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_u64)
                .map(|v| v as usize)
                .with_context(|| format!("manifest lacks {k}"))
        };
        if j.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("unsupported artifact format");
        }
        let mut files = Vec::new();
        if let Some(Json::Obj(arts)) = j.get("artifacts") {
            for (name, meta) in arts {
                let f = meta
                    .get("file")
                    .and_then(Json::as_str)
                    .with_context(|| format!("artifact {name} lacks file"))?;
                files.push((name.clone(), dir.join(f)));
            }
        }
        Ok(Manifest {
            window: get("window")?,
            l1_sets: get("l1_sets")?,
            l1_ways: get("l1_ways")?,
            l2_sets: get("l2_sets")?,
            l2_ways: get("l2_ways")?,
            calib_points: get("calib_points")?,
            sweep_points: get("sweep_points")?,
            files,
        })
    }
}

/// One window's worth of warming output.
#[derive(Clone, Debug)]
pub struct WarmResult {
    pub hit1: Vec<i32>,
    pub hit2: Vec<i32>,
    pub l1: CacheState,
    pub l2: CacheState,
}

/// Kernel-layout cache state (int32 arrays, sets x ways row-major).
#[derive(Clone, Debug)]
pub struct CacheState {
    pub sets: usize,
    pub ways: usize,
    pub tags: Vec<i32>,
    pub valid: Vec<i32>,
    pub dirty: Vec<i32>,
    pub lru: Vec<i32>,
}

impl CacheState {
    pub fn cold(sets: usize, ways: usize) -> Self {
        let n = sets * ways;
        CacheState {
            sets,
            ways,
            tags: vec![0; n],
            valid: vec![0; n],
            dirty: vec![0; n],
            lru: vec![0; n],
        }
    }

    pub fn occupancy(&self) -> usize {
        self.valid.iter().filter(|&&v| v == 1).count()
    }
}

#[cfg(feature = "xla")]
pub struct XlaRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache_warm: xla::PjRtLoadedExecutable,
    calib_step: xla::PjRtLoadedExecutable,
    lat_bw_sweep: xla::PjRtLoadedExecutable,
}

/// Stub runtime used when the crate is built without the `xla` feature
/// (the default — the PJRT bindings pull a large native toolchain).
/// Manifest parsing still works; executing artifacts reports a clear
/// error instead of failing to link. Callers that gate on the presence
/// of `artifacts/manifest.json` (the cross-layer tests, `calibrate`)
/// skip cleanly in fresh checkouts either way.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let _ = manifest;
        bail!(
            "artifacts present at {} but cxlramsim was built without the \
             `xla` feature; rebuild with `--features xla` (adding the \
             `xla` crate to [dependencies]) to execute AOT artifacts",
            dir.display()
        )
    }

    pub fn platform(&self) -> String {
        "stub (xla feature disabled)".into()
    }

    pub fn cache_warm(
        &self,
        _addrs: &[i32],
        _is_write: &[i32],
        _t0: i32,
        _l1: &CacheState,
        _l2: &CacheState,
    ) -> Result<WarmResult> {
        bail!("xla feature disabled")
    }

    pub fn calib_step(
        &self,
        _params: &[f32; 5],
        _loads: &[f32],
        _lat_meas: &[f32],
        _lr: &[f32; 5],
    ) -> Result<([f32; 5], f32)> {
        bail!("xla feature disabled")
    }

    pub fn lat_bw_sweep(
        &self,
        _params: &[f32; 5],
        _loads: &[f32],
    ) -> Result<Vec<f32>> {
        bail!("xla feature disabled")
    }
}

#[cfg(feature = "xla")]
fn load_exe(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = manifest
        .files
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, p)| p.clone())
        .with_context(|| format!("artifact '{name}' not in manifest"))?;
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 path")?,
    )
    .with_context(|| format!("parsing {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {name}"))
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Load every artifact from `dir` (default: ./artifacts).
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let cache_warm = load_exe(&client, &manifest, "cache_warm")?;
        let calib_step = load_exe(&client, &manifest, "calib_step")?;
        let lat_bw_sweep = load_exe(&client, &manifest, "lat_bw_sweep")?;
        Ok(XlaRuntime { manifest, client, cache_warm, calib_step, lat_bw_sweep })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn lit_i32_2d(v: &[i32], sets: usize, ways: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(v).reshape(&[sets as i64, ways as i64])?)
    }

    /// Run one fast-forward window. `addrs` are line addresses;
    /// shorter-than-window batches are masked via the kernel's own
    /// skip-marking (padded with masked-off entries).
    pub fn cache_warm(
        &self,
        addrs: &[i32],
        is_write: &[i32],
        t0: i32,
        l1: &CacheState,
        l2: &CacheState,
    ) -> Result<WarmResult> {
        let n = self.manifest.window;
        if addrs.len() > n || addrs.len() != is_write.len() {
            bail!("window is {n}, got {}", addrs.len());
        }
        // Pad to the static window size; padded entries re-probe address
        // 0 as reads of a masked... the kernel has no mask input in the
        // AOT signature (mask is internal: hit==-1 marks skipped), so we
        // pad with repeats of the last address — harmless for warming —
        // and ignore their outputs.
        let mut a = addrs.to_vec();
        let mut w = is_write.to_vec();
        let pad_addr = *addrs.last().unwrap_or(&0);
        a.resize(n, pad_addr);
        w.resize(n, 0);

        let args = [
            xla::Literal::vec1(&a),
            xla::Literal::vec1(&w),
            xla::Literal::vec1(&[t0]),
            Self::lit_i32_2d(&l1.tags, l1.sets, l1.ways)?,
            Self::lit_i32_2d(&l1.valid, l1.sets, l1.ways)?,
            Self::lit_i32_2d(&l1.dirty, l1.sets, l1.ways)?,
            Self::lit_i32_2d(&l1.lru, l1.sets, l1.ways)?,
            Self::lit_i32_2d(&l2.tags, l2.sets, l2.ways)?,
            Self::lit_i32_2d(&l2.valid, l2.sets, l2.ways)?,
            Self::lit_i32_2d(&l2.dirty, l2.sets, l2.ways)?,
            Self::lit_i32_2d(&l2.lru, l2.sets, l2.ways)?,
        ];
        let result = self.cache_warm.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        if parts.len() != 10 {
            bail!("cache_warm returned {} outputs, want 10", parts.len());
        }
        let take_vec = |l: &xla::Literal| -> Result<Vec<i32>> {
            Ok(l.to_vec::<i32>()?)
        };
        let hit1 = take_vec(&parts[0])?;
        let hit2 = take_vec(&parts[1])?;
        let used = addrs.len();
        let mk_state = |p: &mut [xla::Literal],
                        at: usize,
                        sets: usize,
                        ways: usize|
         -> Result<CacheState> {
            Ok(CacheState {
                sets,
                ways,
                tags: p[at].to_vec::<i32>()?,
                valid: p[at + 1].to_vec::<i32>()?,
                dirty: p[at + 2].to_vec::<i32>()?,
                lru: p[at + 3].to_vec::<i32>()?,
            })
        };
        let l1s = mk_state(&mut parts, 2, l1.sets, l1.ways)?;
        let l2s = mk_state(&mut parts, 6, l2.sets, l2.ways)?;
        Ok(WarmResult {
            hit1: hit1[..used].to_vec(),
            hit2: hit2[..used].to_vec(),
            l1: l1s,
            l2: l2s,
        })
    }

    /// One calibration SGD step. Returns (new params, loss).
    pub fn calib_step(
        &self,
        params: &[f32; 5],
        loads: &[f32],
        lat_meas: &[f32],
        lr: &[f32; 5],
    ) -> Result<([f32; 5], f32)> {
        let m = self.manifest.calib_points;
        if loads.len() != m || lat_meas.len() != m {
            bail!("calib wants {m} points, got {}", loads.len());
        }
        let args = [
            xla::Literal::vec1(&params[..]),
            xla::Literal::vec1(loads),
            xla::Literal::vec1(lat_meas),
            xla::Literal::vec1(&lr[..]),
        ];
        let result = self.calib_step.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let (p, l) = result.to_tuple2()?;
        let pv = p.to_vec::<f32>()?;
        let loss = l.to_vec::<f32>()?[0];
        Ok((pv.try_into().map_err(|_| anyhow::anyhow!("bad params"))?, loss))
    }

    /// Evaluate the latency curve over a load sweep.
    pub fn lat_bw_sweep(
        &self,
        params: &[f32; 5],
        loads: &[f32],
    ) -> Result<Vec<f32>> {
        let m = self.manifest.sweep_points;
        if loads.len() != m {
            bail!("sweep wants {m} points, got {}", loads.len());
        }
        let args =
            [xla::Literal::vec1(&params[..]), xla::Literal::vec1(loads)];
        let result = self.lat_bw_sweep.execute::<xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    //! These tests need `make artifacts` to have run; they are skipped
    //! (not failed) when artifacts/ is absent so `cargo test` works in
    //! a fresh checkout.
    use super::*;

    fn runtime() -> Option<XlaRuntime> {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(XlaRuntime::load(dir).expect("artifacts present but unloadable"))
    }

    #[test]
    fn manifest_geometry_matches_defaults() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.manifest.window, 4096);
        assert_eq!(rt.manifest.l1_sets, 64);
        assert_eq!(rt.manifest.l2_sets, 1024);
    }

    #[test]
    fn cache_warm_runs_and_hits_repeats() {
        let Some(rt) = runtime() else { return };
        let m = &rt.manifest;
        let l1 = CacheState::cold(m.l1_sets, m.l1_ways);
        let l2 = CacheState::cold(m.l2_sets, m.l2_ways);
        // Two passes over 64 lines inside one window: second pass hits L1.
        let addrs: Vec<i32> =
            (0..64).chain(0..64).map(|x| x as i32).collect();
        let writes = vec![0i32; addrs.len()];
        let r = rt.cache_warm(&addrs, &writes, 1, &l1, &l2).unwrap();
        assert!(r.hit1[..64].iter().all(|&h| h == 0), "cold pass misses");
        assert!(r.hit1[64..].iter().all(|&h| h == 1), "warm pass hits L1");
        assert_eq!(r.l1.occupancy(), 64);
        assert_eq!(r.l2.occupancy(), 64);
    }

    #[test]
    fn cache_warm_state_carries_across_windows() {
        let Some(rt) = runtime() else { return };
        let m = &rt.manifest;
        let l1 = CacheState::cold(m.l1_sets, m.l1_ways);
        let l2 = CacheState::cold(m.l2_sets, m.l2_ways);
        let addrs: Vec<i32> = (0..128).collect();
        let writes = vec![0i32; 128];
        let r1 = rt.cache_warm(&addrs, &writes, 1, &l1, &l2).unwrap();
        let r2 = rt
            .cache_warm(&addrs, &writes, 5000, &r1.l1, &r1.l2)
            .unwrap();
        assert!(r2.hit1.iter().all(|&h| h == 1), "window 2 must hit");
    }

    #[test]
    fn calib_converges_toward_truth() {
        let Some(rt) = runtime() else { return };
        let truth = [80.0f32, 25.0, 110.0, 28.0, 40.0];
        let loads: Vec<f32> = (0..rt.manifest.calib_points)
            .map(|i| 0.5 + i as f32)
            .collect();
        // Measured = model(truth) — generated with the sweep artifact's
        // twin formula via calib on itself.
        let meas: Vec<f32> = loads
            .iter()
            .map(|&l| {
                let headroom = ((truth[3] - l) as f64).exp().ln_1p() as f32 + 1e-3;
                truth[0] + 2.0 * truth[1] + truth[2] + truth[4] * l / headroom
            })
            .collect();
        let mut p = [50.0f32, 10.0, 80.0, 20.0, 10.0];
        // Sign-SGD steps with halving decay (mirrors calibrate::Fitter).
        let mut lr = [2.0f32, 2.0, 2.0, 0.5, 0.5];
        let mut first = None;
        let mut last = 0.0;
        for i in 0..1600 {
            let (np, loss) = rt.calib_step(&p, &loads, &meas, &lr).unwrap();
            p = np;
            first.get_or_insert(loss);
            last = loss;
            if (i + 1) % 400 == 0 {
                for x in &mut lr {
                    *x *= 0.5;
                }
            }
        }
        assert!(
            last < first.unwrap() / 10.0,
            "loss {first:?} -> {last} did not converge"
        );
    }

    #[test]
    fn sweep_monotone_under_load() {
        let Some(rt) = runtime() else { return };
        let p = [80.0f32, 25.0, 110.0, 28.0, 40.0];
        let loads: Vec<f32> = (0..rt.manifest.sweep_points)
            .map(|i| 0.1 + i as f32 * 0.15)
            .collect();
        let lat = rt.lat_bw_sweep(&p, &loads).unwrap();
        assert_eq!(lat.len(), loads.len());
        // Latency grows with offered load.
        assert!(lat.last().unwrap() > &(lat[0] + 10.0));
        // Unloaded latency ~ base+2*pkt+media.
        assert!((lat[0] - (80.0 + 50.0 + 110.0)).abs() / lat[0] < 0.2);
    }
}
