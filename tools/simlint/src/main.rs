//! simlint — static enforcement of CXLRAMSim's determinism contract.
//!
//! The simulator promises bit-identical results for a given config at
//! any `(threads, commit_lanes)` setting (docs/ARCHITECTURE.md). That
//! contract is easy to break silently from source: iterate a hash map,
//! read the wall clock inside the model, fold floats in a
//! traversal-dependent order. This binary walks `rust/src` and flags
//! those hazards before they reach a golden digest.
//!
//! Rules (ids are what pragmas and the baseline reference):
//!
//! * `hash-iter`   — iteration over `FxHashMap` / `FxHashSet` /
//!   `HashMap` / `HashSet` (`.iter()`, `.keys()`, `.values()`,
//!   `.drain()`, `for … in`). Hash iteration order depends on the
//!   hasher and insertion history, so anything order-sensitive
//!   downstream diverges. Feed the result through a sort (suppressed
//!   automatically when `.sort` appears in the same statement) or
//!   annotate the site: `// simlint: allow(hash-iter, <reason>)`.
//! * `wall-clock`  — `Instant::now` / `SystemTime` / `std::thread` /
//!   `thread_rng` outside the allowlist (`util/bench.rs`,
//!   `system/machine.rs` wall-clock section timers, `coordinator`).
//! * `float-accum` — `f32`/`f64` accumulation (`.sum::<f64>()`,
//!   `.fold(` with a float seed): float addition is not associative,
//!   so traversal order leaks into the result.
//! * `par-unordered` — rayon-style `par_*` combinators: unordered
//!   reduction outside the machine's deterministic-merge harness.
//!
//! Pre-existing accepted sites live in `tools/simlint/baseline.txt`
//! (content-keyed: `rule<TAB>file<TAB>trimmed line`), so the lint
//! gates only *new* hazards. `--write-baseline` regenerates the file;
//! `--format json` emits a machine-readable report.
//!
//! Exit code: 0 clean (or baselined-only), 1 new findings, 2 usage/IO.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One lint hit. `snippet` is the trimmed source line — together with
/// `rule` and `file` it forms the content key used by the baseline, so
/// unrelated line drift does not invalidate accepted sites.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Finding {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
    snippet: String,
}

impl Finding {
    fn key(&self) -> String {
        format!("{}\t{}\t{}", self.rule, self.file, self.snippet)
    }
}

const HASH_TYPES: [&str; 4] =
    ["FxHashMap<", "FxHashSet<", "HashMap<", "HashSet<"];

/// Method suffixes that enumerate a container in storage order.
const ITER_SUFFIXES: [&str; 7] = [
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".into_iter()",
    ".drain(",
];

/// Files where host-time / host-thread APIs are part of the design:
/// the bench harness measures wall time, the machine's parallel
/// sections use scoped threads + wall-clock phase timers (outside the
/// simulated-time domain), and the coordinator fans whole simulations
/// out across OS threads.
const WALL_ALLOW: [&str; 3] =
    ["util/bench.rs", "system/machine.rs", "coordinator"];

const WALL_TOKENS: [(&str, &str); 5] = [
    ("Instant::now", "wall-clock read"),
    ("SystemTime", "wall-clock read"),
    ("std::thread", "host-thread API"),
    ("thread_rng", "nondeterministic RNG"),
    ("rand::random", "nondeterministic RNG"),
];

const PAR_TOKENS: [&str; 5] = [
    ".par_iter",
    ".into_par_iter",
    ".par_bridge",
    ".par_chunks",
    ".par_sort",
];

fn main() -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut json = false;
    let mut write_baseline = false;
    let mut baseline_path = PathBuf::from("tools/simlint/baseline.txt");

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--format" => match args.next().as_deref() {
                Some("json") => json = true,
                Some("text") => json = false,
                other => {
                    eprintln!(
                        "simlint: --format expects json|text, got {other:?}"
                    );
                    return ExitCode::from(2);
                }
            },
            "--baseline" => match args.next() {
                Some(p) => baseline_path = PathBuf::from(p),
                None => {
                    eprintln!("simlint: --baseline expects a path");
                    return ExitCode::from(2);
                }
            },
            "--write-baseline" => write_baseline = true,
            "--help" | "-h" => {
                println!(
                    "usage: simlint [--format json|text] \
                     [--baseline FILE] [--write-baseline] PATH..."
                );
                return ExitCode::SUCCESS;
            }
            _ => paths.push(PathBuf::from(a)),
        }
    }
    if paths.is_empty() {
        eprintln!("simlint: no paths given (try `simlint rust/src`)");
        return ExitCode::from(2);
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for p in &paths {
        collect_rs(p, &mut files);
    }
    files.sort();

    let mut findings: Vec<Finding> = Vec::new();
    for f in &files {
        let Ok(src) = fs::read_to_string(f) else {
            eprintln!("simlint: cannot read {}", f.display());
            return ExitCode::from(2);
        };
        let rel = f.to_string_lossy().replace('\\', "/");
        findings.extend(scan_file(&rel, &src));
    }
    findings.sort();

    if write_baseline {
        let mut out = String::from(
            "# simlint baseline: accepted pre-existing findings.\n\
             # rule<TAB>file<TAB>trimmed source line (content-keyed).\n",
        );
        for f in &findings {
            out.push_str(&f.key());
            out.push('\n');
        }
        if let Err(e) = fs::write(&baseline_path, out) {
            eprintln!(
                "simlint: cannot write {}: {e}",
                baseline_path.display()
            );
            return ExitCode::from(2);
        }
        println!(
            "simlint: wrote {} entries to {}",
            findings.len(),
            baseline_path.display()
        );
        return ExitCode::SUCCESS;
    }

    let baseline: BTreeSet<String> = fs::read_to_string(&baseline_path)
        .map(|s| {
            s.lines()
                .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();

    let (old, new): (Vec<&Finding>, Vec<&Finding>) =
        findings.iter().partition(|f| baseline.contains(&f.key()));

    if json {
        println!("{}", report_json(&new, old.len(), files.len()));
    } else {
        for f in &new {
            println!(
                "error[{}]: {}\n  --> {}:{}\n   | {}\n",
                f.rule, f.msg, f.file, f.line, f.snippet
            );
        }
        println!(
            "simlint: {} file(s), {} finding(s): {} baselined, {} new",
            files.len(),
            findings.len(),
            old.len(),
            new.len()
        );
    }
    if new.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn collect_rs(p: &Path, out: &mut Vec<PathBuf>) {
    if p.is_dir() {
        let Ok(rd) = fs::read_dir(p) else { return };
        let mut entries: Vec<PathBuf> =
            rd.flatten().map(|e| e.path()).collect();
        entries.sort();
        for e in entries {
            let name = e.file_name().unwrap_or_default().to_string_lossy()
                == "target";
            if !name {
                collect_rs(&e, out);
            }
        }
    } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
        out.push(p.to_path_buf());
    }
}

fn scan_file(rel: &str, src: &str) -> Vec<Finding> {
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    hash_iter_rule(rel, src, &lines, &mut out);
    wall_clock_rule(rel, &lines, &mut out);
    float_accum_rule(rel, &lines, &mut out);
    par_rule(rel, &lines, &mut out);
    out
}

/// `// simlint: allow(rule, reason)` on the flagged line or the line
/// above it. The reason string is mandatory: an allow without a "why"
/// is just a suppressed bug.
fn allowed(lines: &[&str], line_idx: usize, rule: &str) -> bool {
    let check = |l: &str| -> bool {
        let Some(p) = l.find("simlint: allow(") else {
            return false;
        };
        let body = &l[p + "simlint: allow(".len()..];
        let Some(close) = body.find(')') else { return false };
        let body = &body[..close];
        let Some((r, reason)) = body.split_once(',') else {
            return false;
        };
        r.trim() == rule && !reason.trim().is_empty()
    };
    check(lines[line_idx])
        || (line_idx > 0 && check(lines[line_idx - 1]))
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Names bound to hash-ordered containers in this file: struct fields,
/// `let` bindings and fn params whose declared/initialized type is one
/// of [`HASH_TYPES`]. Per-file scoping keeps short names from matching
/// across modules.
fn hash_decl_names(lines: &[&str]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for l in lines {
        let t = l.trim();
        if t.starts_with("//") || t.starts_with("type ")
            || t.starts_with("pub type ")
        {
            continue;
        }
        if !HASH_TYPES.iter().any(|ty| t.contains(ty)) {
            continue;
        }
        if let Some(rest) = t.strip_prefix("let ") {
            let rest = rest.strip_prefix("mut ").unwrap_or(rest);
            let name: String =
                rest.chars().take_while(|&c| is_ident(c)).collect();
            if !name.is_empty() {
                names.insert(name);
            }
            continue;
        }
        // Field or param: `[pub] name: path::HashMap<..>`.
        if let Some(colon) = t.find(':') {
            if let Some(name) = t[..colon].split_whitespace().last() {
                if !name.is_empty() && name.chars().all(is_ident) {
                    names.insert(name.to_string());
                }
            }
        }
    }
    names
}

fn line_of(line_starts: &[usize], off: usize) -> usize {
    match line_starts.binary_search(&off) {
        Ok(i) => i + 1,
        Err(i) => i,
    }
}

fn hash_iter_rule(
    rel: &str,
    src: &str,
    lines: &[&str],
    out: &mut Vec<Finding>,
) {
    let names = hash_decl_names(lines);
    if names.is_empty() {
        return;
    }
    let mut line_starts = vec![0usize];
    for (i, b) in src.bytes().enumerate() {
        if b == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let bytes = src.as_bytes();
    for name in &names {
        for (off, _) in src.match_indices(name.as_str()) {
            // Word boundaries: allow a preceding `.` (field access),
            // reject mid-identifier hits.
            if off > 0 {
                let prev = bytes[off - 1] as char;
                if is_ident(prev) {
                    continue;
                }
            }
            let end = off + name.len();
            if end < bytes.len() && is_ident(bytes[end] as char) {
                continue;
            }
            let lineno = line_of(&line_starts, off);
            let lt = lines[lineno - 1].trim_start();
            if lt.starts_with("//") {
                continue;
            }
            // What follows the name (whitespace/newlines skipped)?
            let tail = src[end..].trim_start();
            let method = ITER_SUFFIXES
                .iter()
                .find(|s| tail.starts_with(**s))
                .copied();
            let line_before =
                &lines[lineno - 1][..off - line_starts[lineno - 1]];
            let for_in = method.is_none()
                && !tail.starts_with('.')
                && line_before.contains("for ")
                && line_before.contains(" in ");
            if method.is_none() && !for_in {
                continue;
            }
            // Sorted downstream in the same statement? Then the order
            // hazard is discharged.
            let mut win_end = (end + 240).min(src.len());
            while !src.is_char_boundary(win_end) {
                win_end -= 1;
            }
            let rest = &src[end..win_end];
            let stmt_end =
                rest.find(';').unwrap_or(rest.len());
            if rest[..stmt_end].contains(".sort") {
                continue;
            }
            if allowed(lines, lineno - 1, "hash-iter") {
                continue;
            }
            let how = method.unwrap_or("for-loop");
            out.push(Finding {
                file: rel.to_string(),
                line: lineno,
                rule: "hash-iter",
                msg: format!(
                    "iteration over hash-ordered container `{name}` \
                     ({how}): order depends on hasher state; sort the \
                     result or annotate \
                     `// simlint: allow(hash-iter, <reason>)`"
                ),
                snippet: lines[lineno - 1].trim().to_string(),
            });
        }
    }
}

fn wall_clock_rule(rel: &str, lines: &[&str], out: &mut Vec<Finding>) {
    if WALL_ALLOW.iter().any(|a| rel.contains(a)) {
        return;
    }
    for (i, l) in lines.iter().enumerate() {
        let t = l.trim();
        if t.starts_with("//") {
            continue;
        }
        for (tok, what) in WALL_TOKENS {
            if t.contains(tok) && !allowed(lines, i, "wall-clock") {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "wall-clock",
                    msg: format!(
                        "{what} `{tok}` in sim-state code: host time / \
                         host threads must not reach the model (see \
                         docs/ARCHITECTURE.md determinism contract)"
                    ),
                    snippet: t.to_string(),
                });
            }
        }
    }
}

fn float_accum_rule(rel: &str, lines: &[&str], out: &mut Vec<Finding>) {
    for (i, l) in lines.iter().enumerate() {
        let t = l.trim();
        if t.starts_with("//") {
            continue;
        }
        let flagged = if t.contains(".sum::<f64>()")
            || t.contains(".sum::<f32>()")
        {
            true
        } else if let Some(p) = t.find(".fold(") {
            // Float seed? Look from the fold's argument list up to the
            // closure, spilling onto the next line for split calls.
            let mut window = t[p + ".fold(".len()..].to_string();
            if let Some(next) = lines.get(i + 1) {
                window.push(' ');
                window.push_str(next.trim());
            }
            let upto = window.find('|').unwrap_or(window.len());
            let seed = &window[..upto];
            seed.contains("0.0") || seed.contains("f64") || seed.contains("f32")
        } else {
            false
        };
        if flagged && !allowed(lines, i, "float-accum") {
            out.push(Finding {
                file: rel.to_string(),
                line: i + 1,
                rule: "float-accum",
                msg: "float accumulation in a traversal: f32/f64 \
                      addition is order-sensitive; accumulate in \
                      integers/ticks or document the fixed traversal \
                      order"
                    .to_string(),
                snippet: t.to_string(),
            });
        }
    }
}

fn par_rule(rel: &str, lines: &[&str], out: &mut Vec<Finding>) {
    for (i, l) in lines.iter().enumerate() {
        let t = l.trim();
        if t.starts_with("//") {
            continue;
        }
        for tok in PAR_TOKENS {
            if t.contains(tok) && !allowed(lines, i, "par-unordered") {
                out.push(Finding {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: "par-unordered",
                    msg: format!(
                        "unordered parallel combinator `{tok}`: \
                         reductions must go through the machine's \
                         deterministic merge, not rayon scheduling"
                    ),
                    snippet: t.to_string(),
                });
            }
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut o = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '"' => o.push_str("\\\""),
            '\\' => o.push_str("\\\\"),
            '\n' => o.push_str("\\n"),
            '\t' => o.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                o.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => o.push(c),
        }
    }
    o
}

fn report_json(new: &[&Finding], baselined: usize, files: usize) -> String {
    let mut s = String::from("{\"findings\":[");
    for (i, f) in new.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\
             \"message\":\"{}\",\"snippet\":\"{}\"}}",
            f.rule,
            json_escape(&f.file),
            f.line,
            json_escape(&f.msg),
            json_escape(&f.snippet)
        ));
    }
    s.push_str(&format!(
        "],\"new\":{},\"baselined\":{},\"files\":{}}}",
        new.len(),
        baselined,
        files
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(src: &str) -> Vec<Finding> {
        scan_file("rust/src/fake.rs", src)
    }

    #[test]
    fn flags_hash_map_iteration_variants() {
        let src = "struct S { m: FxHashMap<u64, u64> }\n\
                   fn f(s: &S) -> u64 {\n\
                   \x20   s.m.iter().map(|(_, v)| *v).max().unwrap_or(0)\n\
                   }\n\
                   fn g(s: &mut S) {\n\
                   \x20   for v in s.m.values() { drop(v); }\n\
                   \x20   s.m.drain();\n\
                   }\n";
        let f = scan(src);
        let hash: Vec<_> =
            f.iter().filter(|f| f.rule == "hash-iter").collect();
        assert_eq!(hash.len(), 3, "{hash:?}");
        assert_eq!(hash[0].line, 3);
    }

    #[test]
    fn flags_multiline_chain_and_for_loop() {
        let src = "struct S { l2_pending: FxHashMap<u64, u64> }\n\
                   impl S {\n\
                   \x20 fn any(&self) -> bool {\n\
                   \x20   self.l2_pending\n\
                   \x20     .keys()\n\
                   \x20     .any(|&k| k > 0)\n\
                   \x20 }\n\
                   \x20 fn each(&self) { for k in &self.l2_pending {\n\
                   \x20   let _ = k; } }\n\
                   }\n";
        let f = scan(src);
        let hash: Vec<_> =
            f.iter().filter(|f| f.rule == "hash-iter").collect();
        assert_eq!(hash.len(), 2, "{hash:?}");
        assert_eq!(hash[0].line, 4, "chain flags at the receiver line");
        assert_eq!(hash[1].line, 8);
    }

    #[test]
    fn sort_in_statement_discharges_hash_iter() {
        let src = "fn f(m: &HashMap<u64, u64>) -> Vec<u64> {\n\
                   \x20 let mut v: Vec<u64> = m.keys().copied()\n\
                   \x20   .collect::<Vec<_>>();\n\
                   \x20 v.sort_unstable();\n\
                   \x20 v\n}\n";
        // `.sort` appears past the `;`, so the collect itself still
        // flags — but piping straight into a sort suppresses:
        let piped = "fn f(m: &HashMap<u64, u64>) -> Vec<u64> {\n\
                   \x20 let mut v: Vec<u64> = m.keys().copied().collect();\n\
                   \x20 v.sort_unstable(); v }\n";
        assert_eq!(
            scan(src).iter().filter(|f| f.rule == "hash-iter").count(),
            1
        );
        let inline = "fn f(m: &HashMap<u64, u64>) -> Vec<u64> {\n\
                   \x20 let mut v: Vec<u64> = m.keys().copied()\n\
                   \x20   .collect::<Vec<_>>(); v.sort_unstable(); v }\n";
        let _ = piped;
        assert_eq!(
            scan(inline)
                .iter()
                .filter(|f| f.rule == "hash-iter")
                .count(),
            1,
            "sort after the `;` does not discharge"
        );
    }

    #[test]
    fn pragma_with_reason_suppresses_without_reason_does_not() {
        let good = "struct S { m: FxHashSet<u64> }\n\
                    fn f(s: &S) -> bool {\n\
                    \x20 // simlint: allow(hash-iter, existence check)\n\
                    \x20 s.m.iter().any(|&k| k > 0)\n}\n";
        let bad = "struct S { m: FxHashSet<u64> }\n\
                   fn f(s: &S) -> bool {\n\
                   \x20 // simlint: allow(hash-iter,)\n\
                   \x20 s.m.iter().any(|&k| k > 0)\n}\n";
        assert_eq!(
            scan(good).iter().filter(|f| f.rule == "hash-iter").count(),
            0
        );
        assert_eq!(
            scan(bad).iter().filter(|f| f.rule == "hash-iter").count(),
            1
        );
    }

    #[test]
    fn wall_clock_banned_outside_allowlist() {
        let src = "fn f() { let _t = Instant::now(); }\n";
        assert_eq!(
            scan_file("rust/src/cxl/link.rs", src)
                .iter()
                .filter(|f| f.rule == "wall-clock")
                .count(),
            1
        );
        assert_eq!(
            scan_file("rust/src/system/machine.rs", src)
                .iter()
                .filter(|f| f.rule == "wall-clock")
                .count(),
            0,
            "machine.rs wall-clock section timers are allowlisted"
        );
    }

    #[test]
    fn float_accum_flags_float_folds_not_integer_folds() {
        let int_fold =
            "fn f(v: &[u8]) -> u8 { v.iter().fold(0u8, |a, b| a ^ b) }\n";
        let float_fold = "fn f(v: &[f64]) -> f64 {\n\
                          \x20 v.iter().fold(\n\
                          \x20   (0.0f64, 0u64),\n\
                          \x20   |a, _| a).0\n}\n";
        let float_sum =
            "fn f(v: &[f64]) -> f64 { v.iter().sum::<f64>() }\n";
        assert_eq!(scan(int_fold).len(), 0);
        assert_eq!(
            scan(float_fold)
                .iter()
                .filter(|f| f.rule == "float-accum")
                .count(),
            1,
            "split-line fold with float seed"
        );
        assert_eq!(
            scan(float_sum)
                .iter()
                .filter(|f| f.rule == "float-accum")
                .count(),
            1
        );
    }

    #[test]
    fn par_combinators_flagged() {
        let src = "fn f(v: &[u8]) { v.par_iter().for_each(|_| ()); }\n";
        assert_eq!(
            scan(src).iter().filter(|f| f.rule == "par-unordered").count(),
            1
        );
    }

    #[test]
    fn baseline_key_is_content_not_line() {
        let f = Finding {
            file: "a.rs".into(),
            line: 10,
            rule: "hash-iter",
            msg: "m".into(),
            snippet: "x.keys()".into(),
        };
        let g = Finding { line: 99, ..f.clone() };
        assert_eq!(f.key(), g.key());
    }

    #[test]
    fn json_report_escapes() {
        let f = Finding {
            file: "a\"b.rs".into(),
            line: 1,
            rule: "wall-clock",
            msg: "tab\there".into(),
            snippet: "x".into(),
        };
        let s = report_json(&[&f], 2, 3);
        assert!(s.contains("a\\\"b.rs"));
        assert!(s.contains("tab\\there"));
        assert!(s.contains("\"baselined\":2"));
    }
}
